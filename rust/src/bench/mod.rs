//! Hand-rolled bench harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p95 reporting, and fixed-width table
//! printing for the paper-figure benches. The [`kernels`] submodule is
//! the `hfl bench` subcommand (blocked vs reference kernel speedups +
//! `BENCH_kernels.json`); [`topo`] is `hfl bench --topo` (fleet scaling
//! up to 10⁶ devices × 10³ edges + `BENCH_topo.json`).

pub mod kernels;
pub mod topo;

use std::time::Instant;

use crate::util::stats;

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.2}ms", s * 1e3)
    } else {
        format!("{:8.3}s ", s)
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
        min_s: stats::min(&samples),
    };
    println!(
        "bench {:40} mean {} p50 {} p95 {} min {} ({} iters)",
        r.name,
        fmt_secs(r.mean_s),
        fmt_secs(r.p50_s),
        fmt_secs(r.p95_s),
        fmt_secs(r.min_s),
        iters
    );
    r
}

/// Time a single invocation (for long-running, end-to-end benches).
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed().as_secs_f64();
    println!("bench {:40} once {}", name, fmt_secs(dt));
    (out, dt)
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let body: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", body.join(" | "));
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["x".into()])
        }));
        assert!(result.is_err());
    }
}
