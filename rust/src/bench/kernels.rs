//! `hfl bench` — the kernel micro/e2e benchmark harness behind the perf
//! trajectory file `BENCH_kernels.json`.
//!
//! Every case times the blocked kernels (`runtime::native::ops`) against
//! the scalar oracles (`ops::reference`) on the same buffers, so the
//! reported speedup is machine-independent enough to regress against: CI
//! runs `hfl bench --smoke --baseline BENCH_kernels.json` and fails when
//! the end-to-end local-round speedup drops more than 25% below the
//! checked-in baseline's (absolute wall-clock is never compared across
//! machines, only the blocked/reference ratio measured on one machine at
//! one moment).
//!
//! `--smoke` restricts to the tiny model and small shapes (seconds, CI
//! friendly); the full run also benches the fmnist-sized shapes the paper
//! sweeps train (448 KB model — the ≥4× acceptance target of PR 2).

use std::path::{Path, PathBuf};

use crate::bench::{bench, BenchResult, Table};
use crate::model::{init_params, Init};
use crate::runtime::native::cnn::NativeCnn;
use crate::runtime::native::ops;
use crate::runtime::native::scratch::ScratchArena;
use crate::util::{Json, Rng};

/// How far the e2e speedup may fall below the baseline's before the
/// regression check fails (the ISSUE's ">25% regression" gate).
const REGRESSION_SLACK: f64 = 0.75;
/// Absolute floor: blocked kernels catastrophically slower than the
/// scalar oracle always fail, baseline or not.
const HARD_FLOOR: f64 = 0.5;

pub struct KernelBenchOpts {
    /// Tiny-model-only quick run (CI).
    pub smoke: bool,
    /// Baseline JSON to regress the e2e speedups against.
    pub baseline: Option<PathBuf>,
    /// Where to write the fresh results JSON.
    pub out: PathBuf,
}

struct Cmp {
    name: String,
    shape: String,
    blocked: BenchResult,
    reference: BenchResult,
}

impl Cmp {
    fn speedup(&self) -> f64 {
        self.reference.mean_s / self.blocked.mean_s.max(1e-12)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("shape", Json::str(&self.shape)),
            ("blocked_ms", Json::num(self.blocked.mean_s * 1e3)),
            ("reference_ms", Json::num(self.reference.mean_s * 1e3)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

fn matmul_cases(smoke: bool, out: &mut Vec<Cmp>) {
    let mut rng = Rng::new(0xBE7C);
    // fmnist fc1 shapes (fwd / dW / dX); smoke shrinks to tiny-fc scale
    let (bsz, n_in, n_out) = if smoke { (8usize, 64usize, 32usize) } else { (8usize, 448usize, 220usize) };
    let iters = if smoke { 40 } else { 30 };

    let x = fill(&mut rng, bsz * n_in);
    let w = fill(&mut rng, n_in * n_out);
    let dy = fill(&mut rng, bsz * n_out);
    let mut y = vec![0.0f32; bsz * n_out];

    let name = format!("matmul_nn_{bsz}x{n_in}x{n_out}");
    let blocked = bench(&format!("{name} blocked"), 3, iters, || {
        ops::matmul(&x, &w, bsz, n_in, n_out, &mut y);
    });
    let reference = bench(&format!("{name} reference"), 3, iters, || {
        ops::reference::matmul(&x, &w, bsz, n_in, n_out, &mut y);
    });
    out.push(Cmp { name, shape: format!("{bsz}x{n_in}x{n_out}"), blocked, reference });

    let mut dw = vec![0.0f32; n_in * n_out];
    let name = format!("matmul_tn_dw_{n_in}x{n_out}_k{bsz}");
    let blocked = bench(&format!("{name} blocked"), 3, iters, || {
        ops::matmul_tn(&x, &dy, bsz, n_in, n_out, &mut dw);
    });
    let reference = bench(&format!("{name} reference"), 3, iters, || {
        ops::reference::matmul_tn(&x, &dy, bsz, n_in, n_out, &mut dw);
    });
    out.push(Cmp { name, shape: format!("k{bsz} {n_in}x{n_out}"), blocked, reference });

    let mut dx = vec![0.0f32; bsz * n_in];
    let name = format!("matmul_nt_dx_{bsz}x{n_out}x{n_in}");
    let blocked = bench(&format!("{name} blocked"), 3, iters, || {
        ops::matmul_nt(&dy, &w, bsz, n_out, n_in, &mut dx);
    });
    let reference = bench(&format!("{name} reference"), 3, iters, || {
        ops::reference::matmul_nt(&dy, &w, bsz, n_out, n_in, &mut dx);
    });
    out.push(Cmp { name, shape: format!("{bsz}x{n_out}x{n_in}"), blocked, reference });
}

fn conv_cases(smoke: bool, out: &mut Vec<Cmp>) {
    let mut rng = Rng::new(0xC0Fb);
    // fmnist conv2 (the dominant GEMM of the 448 KB model); smoke = tiny conv
    let (bsz, ic, ih, oc, k) =
        if smoke { (8usize, 1usize, 10usize, 4usize, 3usize) } else { (8usize, 15usize, 12usize, 28usize, 5usize) };
    let iters = if smoke { 30 } else { 15 };
    let oh = ih - k + 1;
    let (kk, ohw) = (ic * k * k, oh * oh);

    let x = fill(&mut rng, bsz * ic * ih * ih);
    let w = fill(&mut rng, oc * kk);
    let b = fill(&mut rng, oc);
    let dy = fill(&mut rng, bsz * oc * ohw);
    let mut y = vec![0.0f32; bsz * oc * ohw];
    let mut cols = vec![0.0f32; bsz * kk * ohw];

    let name = format!("conv2d_fwd_b{bsz}_{ic}x{ih}x{ih}_oc{oc}_k{k}");
    let blocked = bench(&format!("{name} blocked"), 2, iters, || {
        ops::conv2d_fwd_cols(&x, &w, &b, bsz, ic, ih, ih, oc, k, true, &mut cols, &mut y);
    });
    let reference = bench(&format!("{name} reference"), 2, iters, || {
        ops::reference::conv2d_fwd(&x, &w, &b, bsz, ic, ih, ih, oc, k, true, &mut y);
    });
    out.push(Cmp {
        name,
        shape: format!("b{bsz} {ic}x{ih}x{ih} -> {oc}x{oh}x{oh} k{k}"),
        blocked,
        reference,
    });

    // backward reuses the forward's im2col cache — that is the hot path
    ops::conv2d_fwd_cols(&x, &w, &b, bsz, ic, ih, ih, oc, k, true, &mut cols, &mut y);
    let mut dw = vec![0.0f32; oc * kk];
    let mut db = vec![0.0f32; oc];
    let mut dx = vec![0.0f32; bsz * ic * ih * ih];
    let mut dcol = vec![0.0f32; kk * ohw];
    let name = format!("conv2d_bwd_b{bsz}_{ic}x{ih}x{ih}_oc{oc}_k{k}");
    let blocked = bench(&format!("{name} blocked"), 2, iters, || {
        ops::conv2d_bwd_cols(
            &cols, &w, &dy, bsz, ic, ih, ih, oc, k, &mut dw, &mut db, Some(&mut dx), &mut dcol,
        );
    });
    let reference = bench(&format!("{name} reference"), 2, iters, || {
        ops::reference::conv2d_bwd(
            &x, &w, &dy, bsz, ic, ih, ih, oc, k, &mut dw, &mut db, Some(&mut dx),
        );
    });
    out.push(Cmp {
        name,
        shape: format!("b{bsz} {ic}x{ih}x{ih} -> {oc}x{oh}x{oh} k{k}"),
        blocked,
        reference,
    });
}

fn model_for(name: &str) -> NativeCnn {
    // same registry the backend trains with — the bench can never
    // measure a geometry the sweeps don't run
    crate::runtime::native::builtin_model(name)
        .unwrap_or_else(|| panic!("no bench model {name:?}"))
}

/// End-to-end local round (L SGD steps of minibatch B, the
/// `Backend::local_round` per-slot unit): blocked kernels + warm arena
/// vs. the PR 1 scalar kernels.
fn e2e_case(model: &str, iters: usize, out: &mut Vec<Cmp>) {
    let m = model_for(model);
    let (l, bsz) = (5usize, 8usize);
    let mut rng = Rng::new(0xE2E0);
    let base = init_params(&m.info, Init::HeNormal, &mut rng);
    let xs = fill(&mut rng, l * bsz * m.pixels());
    let mut ys = vec![0.0f32; l * bsz * crate::data::NUM_CLASSES];
    for s in 0..l * bsz {
        ys[s * crate::data::NUM_CLASSES + s % crate::data::NUM_CLASSES] = 1.0;
    }
    let mut params = base.clone();
    let mut arena = ScratchArena::new();
    // warm the arena outside the timed region (steady-state sweep behavior)
    params.copy_from_slice(&base);
    m.local_round_arena(&mut params, &xs, &ys, l, bsz, 0.01, &mut arena);

    let name = format!("local_round_{model}");
    let blocked = bench(&format!("{name} blocked"), 1, iters, || {
        params.copy_from_slice(&base);
        m.local_round_arena(&mut params, &xs, &ys, l, bsz, 0.01, &mut arena);
    });
    let reference = bench(&format!("{name} reference"), 1, iters, || {
        params.copy_from_slice(&base);
        m.local_round_reference(&mut params, &xs, &ys, l, bsz, 0.01);
    });
    out.push(Cmp {
        name,
        shape: format!("{model} L{l} B{bsz} ({} params)", m.info.params),
        blocked,
        reference,
    });
}

fn check_against_baseline(e2e: &[Cmp], path: &Path) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read baseline {}: {e}", path.display()))?;
    let base = Json::parse(&text).map_err(|e| anyhow::anyhow!("baseline {}: {e}", path.display()))?;
    // fail-soft annotation, not an error: a floor baseline still gates
    // catastrophic regressions, it just can't catch honest 25% slowdowns
    if base.get("mode").and_then(Json::as_str) == Some("floor") {
        println!(
            "NOTE: baseline {} is still a bootstrap FLOOR (mode: \"floor\"), not a \
             measured run — the gate only catches catastrophic slowdowns. Arm it by \
             replacing the committed file with the measured JSON this run printed \
             (the CI full-bench step emits it as a copy-pasteable block).",
            path.display()
        );
    }
    let entries = match base.get("e2e").and_then(Json::as_arr) {
        Some(a) => a,
        None => {
            log::warn!(
                "baseline {} has no e2e entries (bootstrap file?) — skipping regression check",
                path.display()
            );
            return Ok(());
        }
    };
    for cur in e2e {
        let prev = entries.iter().find(|e| {
            e.get("name").and_then(Json::as_str) == Some(cur.name.as_str())
        });
        let prev_speedup = match prev.and_then(|e| e.get("speedup")).and_then(Json::as_f64) {
            Some(s) => s,
            None => {
                log::warn!("baseline has no speedup for {} — not regressed-checked", cur.name);
                continue;
            }
        };
        let cur_speedup = cur.speedup();
        anyhow::ensure!(
            cur_speedup >= prev_speedup * REGRESSION_SLACK,
            "{}: e2e speedup regressed >25%: {cur_speedup:.2}x now vs {prev_speedup:.2}x in {}",
            cur.name,
            path.display()
        );
        println!(
            "baseline check {:24} ok: {cur_speedup:.2}x vs baseline {prev_speedup:.2}x",
            cur.name
        );
    }
    Ok(())
}

fn results_json(mode: &str, kernels: &[Cmp], e2e: &[Cmp]) -> Json {
    Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("mode", Json::str(mode)),
        (
            "generated_by",
            Json::str("hfl bench (blocked runtime::native kernels vs ops::reference scalar oracle)"),
        ),
        ("kernels", Json::Arr(kernels.iter().map(Cmp::to_json).collect())),
        ("e2e", Json::Arr(e2e.iter().map(Cmp::to_json).collect())),
    ])
}

/// Run the harness; returns the e2e speedup of the largest benched model
/// (tiny in smoke mode, fmnist otherwise).
pub fn run(opts: &KernelBenchOpts) -> anyhow::Result<f64> {
    let mode = if opts.smoke { "smoke" } else { "full" };
    println!("hfl bench [{mode}]: blocked kernels vs scalar reference oracle");

    let mut kernels: Vec<Cmp> = Vec::new();
    matmul_cases(opts.smoke, &mut kernels);
    conv_cases(opts.smoke, &mut kernels);

    let mut e2e: Vec<Cmp> = Vec::new();
    e2e_case("tiny", if opts.smoke { 10 } else { 8 }, &mut e2e);
    if !opts.smoke {
        e2e_case("fmnist", 3, &mut e2e);
    }

    let mut table = Table::new(&["case", "shape", "blocked", "reference", "speedup"]);
    for c in kernels.iter().chain(e2e.iter()) {
        table.row(&[
            c.name.clone(),
            c.shape.clone(),
            format!("{:.3}ms", c.blocked.mean_s * 1e3),
            format!("{:.3}ms", c.reference.mean_s * 1e3),
            format!("{:.2}x", c.speedup()),
        ]);
    }
    table.print();

    let json = results_json(mode, &kernels, &e2e);
    let mut text = String::new();
    json.write(&mut text);
    text.push('\n');
    if let Some(dir) = opts.out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).ok();
        }
    }
    std::fs::write(&opts.out, &text)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", opts.out.display()))?;
    println!("wrote {}", opts.out.display());

    let headline = e2e.last().expect("at least one e2e case");
    let headline_speedup = headline.speedup();
    println!(
        "e2e {}: {:.2}x vs scalar reference (blocked {:.2}ms, reference {:.2}ms)",
        headline.name,
        headline_speedup,
        headline.blocked.mean_s * 1e3,
        headline.reference.mean_s * 1e3,
    );
    // only meaningful on optimized builds: the test profile (opt-level 1,
    // debug assertions) deliberately skips the absolute floor
    anyhow::ensure!(
        cfg!(debug_assertions) || headline_speedup >= HARD_FLOOR,
        "blocked kernels are >2x slower than the scalar reference ({headline_speedup:.2}x) — \
         something is badly wrong with the blocked path on this host"
    );
    if let Some(baseline) = &opts.baseline {
        check_against_baseline(&e2e, baseline)?;
    }
    Ok(headline_speedup)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_writes_parseable_json() {
        let out = std::env::temp_dir().join(format!("hfl_bench_{}.json", std::process::id()));
        let opts = KernelBenchOpts { smoke: true, baseline: None, out: out.clone() };
        let speedup = run(&opts).unwrap();
        assert!(speedup.is_finite() && speedup > 0.0);
        let text = std::fs::read_to_string(&out).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("mode").and_then(Json::as_str), Some("smoke"));
        assert!(j.get("e2e").and_then(Json::as_arr).map(|a| !a.is_empty()).unwrap_or(false));
        std::fs::remove_file(&out).ok();
    }
}
