//! `hfl bench --topo` — the topology scaling suite behind
//! `BENCH_topo.json`: N = 10³..10⁶ devices against M = N/1000 (clamped to
//! [5, 1000]) edge servers, measuring generation time, one full
//! schedule→assign→cost round, and resident topology memory.
//!
//! Every size past the dense budget exercises the scalable path: per-device
//! RNG streams, the sparse k-nearest gain table, and the equal-split
//! [`CostCache`] — the dense N×M gain matrix (8·N·M bytes; 8 GB at
//! 10⁶×10³) is never allocated, which is the point of the suite. The
//! per-round pipeline is IKC scheduling over K=10 synthetic index clusters
//! (H = N/10), geographic assignment via the cached nearest-edge indices,
//! and a full objective-(17) evaluation through the cache.
//!
//! Wall-clock numbers are machine-dependent, so the regression gate is
//! relative like the kernel bench's: against a *measured* baseline entry,
//! rounds/s may not drop below 50% and bytes/device may not grow past
//! 125%; against a bootstrap *floor* entry (no `rounds_per_s`), only the
//! absolute `max_bytes_per_device` ceiling is enforced — memory per device
//! is a deterministic property of the layout, not of the host.

use std::path::{Path, PathBuf};

use crate::allocation::CostCache;
use crate::assignment::geo::assign_geographic;
use crate::scheduling::{Ikc, Scheduler};
use crate::system::{SystemParams, Topology, DENSE_GAIN_BUDGET};
use crate::util::{Json, Rng};

use super::{bench_once, Table};

/// Measured rounds/s may not drop below this fraction of the baseline's.
const SPEED_SLACK: f64 = 0.5;
/// Measured bytes/device may not exceed this multiple of the baseline's.
const MEM_SLACK: f64 = 1.25;
/// Synthetic cluster count for the IKC scheduling stage (devices are
/// binned by `n % K`; class-balance structure is irrelevant to timing).
const K_CLUSTERS: usize = 10;

pub struct TopoBenchOpts {
    /// CI quick run: stop at N = 10⁵.
    pub smoke: bool,
    /// Baseline JSON (`BENCH_topo.json`) to gate against.
    pub baseline: Option<PathBuf>,
    /// Where to write the fresh results JSON.
    pub out: PathBuf,
}

struct SizeResult {
    n: usize,
    m: usize,
    gain_mode: &'static str,
    gen_s: f64,
    round_s: f64,
    topo_bytes: usize,
}

impl SizeResult {
    fn rounds_per_s(&self) -> f64 {
        if self.round_s > 0.0 {
            1.0 / self.round_s
        } else {
            f64::INFINITY
        }
    }

    fn bytes_per_device(&self) -> f64 {
        self.topo_bytes as f64 / self.n as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("m", Json::num(self.m as f64)),
            ("gain_mode", Json::str(self.gain_mode)),
            ("gen_s", Json::num(self.gen_s)),
            ("round_s", Json::num(self.round_s)),
            ("rounds_per_s", Json::num(self.rounds_per_s())),
            ("topo_bytes", Json::num(self.topo_bytes as f64)),
            ("bytes_per_device", Json::num(self.bytes_per_device())),
            (
                "dense_equivalent_bytes",
                Json::num((self.n * self.m * 8) as f64),
            ),
        ])
    }
}

fn params_for(n: usize) -> SystemParams {
    SystemParams {
        n_devices: n,
        n_edges: (n / 1000).clamp(5, 1000),
        ..SystemParams::default()
    }
}

/// One schedule→assign→cost round at size `n` (the sweep loop's per-round
/// work, minus FL training, which scales with H·model, not with N).
fn run_size(n: usize) -> SizeResult {
    let params = params_for(n);
    let m = params.n_edges;
    let (topo, gen_s) =
        bench_once(&format!("topo_gen_n{n}_m{m}"), || Topology::generate(&params, &mut Rng::new(42)));

    let h = (n / 10).max(1);
    let clusters: Vec<Vec<usize>> = (0..K_CLUSTERS)
        .map(|k| (0..n).filter(|d| d % K_CLUSTERS == k).collect())
        .collect();
    let h_round = h - h % K_CLUSTERS;
    let mut ikc = Ikc::new(clusters, n, h_round.max(K_CLUSTERS), 7);
    let mut cache = CostCache::new_equal_split(params.lambda);

    let ((), round_s) = bench_once(&format!("topo_round_n{n}_m{m}"), || {
        let scheduled = ikc.schedule();
        let a = assign_geographic(&topo, &scheduled);
        cache.reset(&topo, &a.groups);
        let c = cache.iter_cost();
        assert!(c.t.is_finite() && c.e.is_finite());
    });

    SizeResult {
        n,
        m,
        gain_mode: if topo.is_lazy_gains() { "lazy" } else { "dense" },
        gen_s,
        round_s,
        topo_bytes: topo.mem_bytes(),
    }
}

fn check_against_baseline(results: &[SizeResult], path: &Path) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read baseline {}: {e}", path.display()))?;
    let base =
        Json::parse(&text).map_err(|e| anyhow::anyhow!("baseline {}: {e}", path.display()))?;
    // fail-soft annotation, not an error: a floor baseline gates only the
    // deterministic memory ceilings, never measured throughput
    if base.get("mode").and_then(Json::as_str) == Some("floor") {
        println!(
            "NOTE: baseline {} is still a bootstrap FLOOR (mode: \"floor\") — \
             throughput is not regression-gated. Arm it by replacing the committed \
             file with the measured JSON this run printed (the CI full-bench step \
             emits it as a copy-pasteable block).",
            path.display()
        );
    }
    let entries = match base.get("sizes").and_then(Json::as_arr) {
        Some(a) => a,
        None => {
            log::warn!(
                "baseline {} has no sizes entries — skipping regression check",
                path.display()
            );
            return Ok(());
        }
    };
    for cur in results {
        let prev = entries
            .iter()
            .find(|e| e.get("n").and_then(Json::as_f64) == Some(cur.n as f64));
        let prev = match prev {
            Some(p) => p,
            None => {
                log::warn!("baseline has no entry for N={} — not gated", cur.n);
                continue;
            }
        };
        // always-on floor: memory layout is deterministic per device count
        if let Some(ceiling) = prev.get("max_bytes_per_device").and_then(Json::as_f64) {
            anyhow::ensure!(
                cur.bytes_per_device() <= ceiling,
                "N={}: {:.1} bytes/device exceeds the {ceiling:.1} ceiling in {}",
                cur.n,
                cur.bytes_per_device(),
                path.display()
            );
            println!(
                "baseline check N={:<8} mem ok: {:.1} B/dev <= {ceiling:.1} B/dev floor",
                cur.n,
                cur.bytes_per_device()
            );
        }
        // measured entries additionally gate relative throughput + memory
        if let Some(prev_rps) = prev.get("rounds_per_s").and_then(Json::as_f64) {
            let cur_rps = cur.rounds_per_s();
            anyhow::ensure!(
                cur_rps >= prev_rps * SPEED_SLACK,
                "N={}: rounds/s regressed >50%: {cur_rps:.3} now vs {prev_rps:.3} in {}",
                cur.n,
                path.display()
            );
            println!(
                "baseline check N={:<8} speed ok: {cur_rps:.3} rounds/s vs baseline {prev_rps:.3}",
                cur.n
            );
        }
        if let Some(prev_bpd) = prev.get("bytes_per_device").and_then(Json::as_f64) {
            anyhow::ensure!(
                cur.bytes_per_device() <= prev_bpd * MEM_SLACK,
                "N={}: bytes/device grew >25%: {:.1} now vs {prev_bpd:.1} in {}",
                cur.n,
                cur.bytes_per_device(),
                path.display()
            );
        }
    }
    Ok(())
}

fn results_json(mode: &str, results: &[SizeResult]) -> Json {
    Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("mode", Json::str(mode)),
        (
            "generated_by",
            Json::str("hfl bench --topo (fleet generation + schedule/assign/cost round at scale)"),
        ),
        ("sizes", Json::Arr(results.iter().map(SizeResult::to_json).collect())),
    ])
}

/// Run the scaling suite; returns the largest size's rounds/s.
pub fn run(opts: &TopoBenchOpts) -> anyhow::Result<f64> {
    let mode = if opts.smoke { "smoke" } else { "full" };
    println!("hfl bench --topo [{mode}]: fleet scaling suite");

    let sizes: &[usize] = if opts.smoke {
        &[1_000, 10_000, 100_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    let results: Vec<SizeResult> = sizes.iter().map(|&n| run_size(n)).collect();

    let mut table = Table::new(&[
        "N", "M", "gains", "gen", "round", "rounds/s", "topo mem", "B/dev", "dense would be",
    ]);
    for r in &results {
        table.row(&[
            format!("{}", r.n),
            format!("{}", r.m),
            r.gain_mode.to_string(),
            format!("{:.3}s", r.gen_s),
            format!("{:.3}s", r.round_s),
            format!("{:.3}", r.rounds_per_s()),
            format!("{:.1} MB", r.topo_bytes as f64 / 1e6),
            format!("{:.0}", r.bytes_per_device()),
            format!("{:.1} MB", (r.n * r.m * 8) as f64 / 1e6),
        ]);
    }
    table.print();

    let json = results_json(mode, &results);
    let mut text = String::new();
    json.write(&mut text);
    text.push('\n');
    if let Some(dir) = opts.out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).ok();
        }
    }
    std::fs::write(&opts.out, &text)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", opts.out.display()))?;
    println!("wrote {}", opts.out.display());

    // structural sanity independent of the host: scalable sizes must not
    // have paid for the dense matrix
    for r in &results {
        if r.n * r.m > DENSE_GAIN_BUDGET {
            anyhow::ensure!(
                r.gain_mode == "lazy" && r.topo_bytes < r.n * r.m * 8,
                "N={} should be lazy/sparse but reports {} bytes (dense would be {})",
                r.n,
                r.topo_bytes,
                r.n * r.m * 8
            );
        }
    }

    if let Some(baseline) = &opts.baseline {
        check_against_baseline(&results, baseline)?;
    }
    let headline = results.last().expect("at least one size");
    println!(
        "largest size N={} M={}: {:.3} rounds/s, {:.1} MB topology ({:.0} B/device)",
        headline.n,
        headline.m,
        headline.rounds_per_s(),
        headline.topo_bytes as f64 / 1e6,
        headline.bytes_per_device()
    );
    Ok(headline.rounds_per_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_clamp_edge_counts() {
        assert_eq!(params_for(1_000).n_edges, 5);
        assert_eq!(params_for(100_000).n_edges, 100);
        assert_eq!(params_for(1_000_000).n_edges, 1000);
        assert_eq!(params_for(5_000_000).n_edges, 1000);
    }

    #[test]
    fn single_size_result_is_sane() {
        let r = run_size(1_000);
        assert_eq!(r.n, 1_000);
        assert_eq!(r.m, 5);
        assert_eq!(r.gain_mode, "dense");
        assert!(r.gen_s >= 0.0 && r.round_s >= 0.0);
        assert!(r.topo_bytes > 1_000 * 36);
    }

    #[test]
    fn results_json_round_trips() {
        let r = SizeResult {
            n: 1000,
            m: 5,
            gain_mode: "dense",
            gen_s: 0.01,
            round_s: 0.02,
            topo_bytes: 76_000,
        };
        let j = results_json("smoke", &[r]);
        let mut text = String::new();
        j.write(&mut text);
        let back = Json::parse(&text).unwrap();
        let sizes = back.get("sizes").and_then(Json::as_arr).unwrap();
        assert_eq!(sizes.len(), 1);
        assert_eq!(sizes[0].get("n").and_then(Json::as_f64), Some(1000.0));
        assert!(sizes[0].get("rounds_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
