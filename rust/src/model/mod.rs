//! Flat-parameter-vector model handling on the Rust side.
//!
//! Models cross the Rust↔HLO boundary as flat f32 vectors whose leaf
//! layout comes from `artifacts/manifest.json`. This module provides
//! initialization (He-normal [41] for the CNNs, Glorot-uniform for the
//! D³QN — matching `python/compile/{model,dqn}.py`) and the weighted
//! parameter averaging used by edge aggregation (eq. 2) and cloud
//! aggregation (eq. 3).

use crate::runtime::ModelInfo;
use crate::util::Rng;

/// Initialization family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    /// He-normal on weights, zero biases — the CNN / mini models.
    HeNormal,
    /// Glorot-uniform on weights, zero biases — the D³QN.
    GlorotUniform,
}

/// Output-head leaves are initialized 10× smaller: full-scale He gives
/// initial logits with std ≫ 1 and plain SGD at the paper's learning rates
/// stalls (mirrors `OUTPUT_SCALE` in python/compile/model.py).
const OUTPUT_SCALE: f32 = 0.1;

fn output_scale(name: &str) -> f32 {
    match name {
        "fc2_w" | "fc_w" | "v_w" | "a_w" => OUTPUT_SCALE,
        _ => 1.0,
    }
}

/// Initialize a flat parameter vector for `info`.
pub fn init_params(info: &ModelInfo, init: Init, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; info.params];
    for leaf in &info.leaves {
        let dst = &mut out[leaf.offset..leaf.offset + leaf.size];
        if leaf.is_bias() {
            continue; // zeros
        }
        let mut v = match init {
            Init::HeNormal => rng.he_normal(leaf.size, leaf.fan_in()),
            Init::GlorotUniform => {
                rng.glorot_uniform(leaf.size, leaf.fan_in(), leaf.fan_out())
            }
        };
        let s = output_scale(&leaf.name);
        if s != 1.0 {
            for x in v.iter_mut() {
                *x *= s;
            }
        }
        dst.copy_from_slice(&v);
    }
    out
}

/// Weighted average of parameter vectors: `Σ w_i·p_i / Σ w_i`
/// (eq. 2 with w = D_n; eq. 3 with w = D_{N_m}).
pub fn weighted_average(params: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    assert_eq!(params.len(), weights.len());
    assert!(!params.is_empty(), "weighted_average of nothing");
    let dim = params[0].len();
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "zero total weight");
    let mut out = vec![0.0f64; dim];
    for (p, &w) in params.iter().zip(weights) {
        assert_eq!(p.len(), dim, "parameter dim mismatch");
        let scale = w / total;
        for (o, &x) in out.iter_mut().zip(p.iter()) {
            *o += scale * x as f64;
        }
    }
    out.into_iter().map(|x| x as f32).collect()
}

/// In-place axpy-style accumulate used by streaming aggregation:
/// `acc += w * p` (caller divides by total weight at the end).
pub fn accumulate(acc: &mut [f64], p: &[f32], w: f64) {
    assert_eq!(acc.len(), p.len());
    for (a, &x) in acc.iter_mut().zip(p.iter()) {
        *a += w * x as f64;
    }
}

/// Finish a streaming aggregation.
pub fn finish(acc: &[f64], total_weight: f64) -> Vec<f32> {
    assert!(total_weight > 0.0);
    acc.iter().map(|&x| (x / total_weight) as f32).collect()
}

/// L2 distance between two parameter vectors (clustering, tests).
pub fn l2_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Leaf;

    fn info() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            params: 16 * 4 + 4,
            bytes: (16 * 4 + 4) * 4,
            leaves: vec![
                Leaf { name: "w".into(), shape: vec![16, 4], offset: 0, size: 64 },
                Leaf { name: "w_b".into(), shape: vec![4], offset: 64, size: 4 },
            ],
        }
    }

    #[test]
    fn init_he_bias_zero_weights_nonzero() {
        let p = init_params(&info(), Init::HeNormal, &mut Rng::new(1));
        assert_eq!(p.len(), 68);
        assert!(p[..64].iter().any(|&x| x != 0.0));
        assert!(p[64..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn init_glorot_within_limit() {
        let p = init_params(&info(), Init::GlorotUniform, &mut Rng::new(2));
        let lim = (6.0f64 / (16.0 + 4.0)).sqrt() as f32;
        assert!(p[..64].iter().all(|&x| x.abs() <= lim));
    }

    #[test]
    fn weighted_average_matches_eq2() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        // D_a = 1, D_b = 3 -> (1*a + 3*b)/4 = [2.5, 5.0]
        let avg = weighted_average(&[&a, &b], &[1.0, 3.0]);
        assert_eq!(avg, vec![2.5, 5.0]);
    }

    #[test]
    fn weighted_average_identity_for_single() {
        let a = vec![1.5f32, -2.0];
        assert_eq!(weighted_average(&[&a], &[7.0]), a);
    }

    #[test]
    fn streaming_equals_batch() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![-1.0f32, 0.5, 2.0];
        let batch = weighted_average(&[&a, &b], &[2.0, 5.0]);
        let mut acc = vec![0.0f64; 3];
        accumulate(&mut acc, &a, 2.0);
        accumulate(&mut acc, &b, 5.0);
        let stream = finish(&acc, 7.0);
        for (x, y) in batch.iter().zip(stream.iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panic() {
        let a = vec![1.0f32];
        let b = vec![1.0f32, 2.0];
        weighted_average(&[&a, &b], &[1.0, 1.0]);
    }

    #[test]
    fn l2_distance_basic() {
        assert_eq!(l2_distance(&[0.0, 3.0], &[4.0, 0.0]), 5.0);
    }
}
