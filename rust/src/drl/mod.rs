//! Deep reinforcement learning for device assignment (§V): episode feature
//! construction (eqs. 24–25), the replay buffer Ω, the Algorithm 5 training
//! loop and flat-parameter checkpoints.
//!
//! Inference AND training are backend-portable: both dispatch through
//! [`crate::runtime::Backend`] (`dqn_q_all` / `dqn_train_step`), so
//! Algorithm 5 runs artifact-free on the native backend — per-cell agents
//! in sweeps included (`d3qn?train=percell`) — while pjrt builds can
//! replay the same loop on the AOT artifacts as a parity oracle.

pub mod checkpoint;
pub mod episode;
pub mod replay;
pub mod trainer;

pub use episode::{build_features, EpisodeFeatures};
pub use replay::{Batch, ReplayBuffer, Transition};
pub use trainer::{DqnTrainConfig, DqnTrainer, TrainResult};
