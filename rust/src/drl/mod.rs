//! Deep reinforcement learning for device assignment (§V): episode feature
//! construction (eqs. 24–25), the replay buffer Ω, the Algorithm 5 training
//! loop and flat-parameter checkpoints.
//!
//! Inference is backend-portable (see `assignment::drl`); the Algorithm 5
//! *training* loop still drives the `dqn_train` AOT artifact directly and
//! therefore requires the `pjrt` feature (porting it to the native backend
//! is a ROADMAP open item).

pub mod checkpoint;
pub mod episode;
pub mod replay;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use episode::{build_features, EpisodeFeatures};
pub use replay::{Batch, ReplayBuffer, Transition};
#[cfg(feature = "pjrt")]
pub use trainer::{DqnTrainConfig, DqnTrainer, TrainResult};
