//! Deep reinforcement learning for device assignment (§V): episode feature
//! construction (eqs. 24–25), the replay buffer Ω, the Algorithm 5 training
//! loop and flat-parameter checkpoints.

pub mod checkpoint;
pub mod episode;
pub mod replay;
pub mod trainer;

pub use episode::{build_features, EpisodeFeatures};
pub use replay::{Batch, ReplayBuffer, Transition};
pub use trainer::{DqnTrainConfig, DqnTrainer, TrainResult};
