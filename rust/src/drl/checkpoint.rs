//! Tiny binary checkpoint format for flat f32 parameter vectors.
//!
//! Layout: magic `HFLTHET1` (8 bytes) | u64 LE element count | f32 LE data.

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"HFLTHET1";

pub fn save_params(path: &Path, params: &[f32]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for &x in params {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&bytes)?;
    Ok(())
}

pub fn load_params(path: &Path) -> anyhow::Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "{} is not a theta checkpoint", path.display());
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let len = u64::from_le_bytes(lenb) as usize;
    let mut bytes = vec![0u8; len * 4];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("hfl_ckpt_test");
        let path = dir.join("theta.bin");
        let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        save_params(&path, &params).unwrap();
        let back = load_params(&path).unwrap();
        assert_eq!(params, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("hfl_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
