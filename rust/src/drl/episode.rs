//! D³QN episode features (eqs. 24–25).
//!
//! The state of an assignment episode is the min–max-normalized feature
//! sequence `χ_{n_1}..χ_{n_H}`, `χ_n = (g̃_n^1..g̃_n^M, ũ_n, D̃_n, p̃_n)`.
//! Normalization is column-wise over the scheduled set, so features land in
//! [0,1] regardless of the iteration's device draw.

use crate::system::Topology;

/// Row-major `(H, F)` feature matrix for one episode.
#[derive(Clone, Debug)]
pub struct EpisodeFeatures {
    pub feats: Vec<f32>,
    pub h: usize,
    pub f: usize,
}

/// Build raw (unnormalized) features for one device.
fn raw_features(topo: &Topology, n: usize, out: &mut [f64]) {
    let d = topo.device(n);
    let m = topo.edges.len();
    for j in 0..m {
        // gains span orders of magnitude: normalize in log domain
        out[j] = topo.gain(n, j).log10();
    }
    out[m] = d.cycles_per_sample;
    out[m + 1] = d.num_samples as f64;
    out[m + 2] = d.tx_power_w;
}

/// Eq. 24–25: features for `scheduled` (episode device order = slice order).
pub fn build_features(topo: &Topology, scheduled: &[usize]) -> EpisodeFeatures {
    let m = topo.edges.len();
    let f = m + 3;
    let h = scheduled.len();
    let mut raw = vec![0.0f64; h * f];
    for (t, &n) in scheduled.iter().enumerate() {
        raw_features(topo, n, &mut raw[t * f..(t + 1) * f]);
    }
    // column-wise min–max normalization
    let mut feats = vec![0.0f32; h * f];
    for c in 0..f {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for t in 0..h {
            lo = lo.min(raw[t * f + c]);
            hi = hi.max(raw[t * f + c]);
        }
        let span = hi - lo;
        for t in 0..h {
            feats[t * f + c] = if span > 0.0 {
                ((raw[t * f + c] - lo) / span) as f32
            } else {
                0.5
            };
        }
    }
    EpisodeFeatures { feats, h, f }
}

impl EpisodeFeatures {
    /// Zero-pad (or truncate is forbidden) to a larger horizon.
    pub fn pad_to(&self, horizon: usize) -> EpisodeFeatures {
        assert!(horizon >= self.h, "cannot truncate an episode");
        let mut feats = vec![0.0f32; horizon * self.f];
        feats[..self.h * self.f].copy_from_slice(&self.feats);
        EpisodeFeatures { feats, h: horizon, f: self.f }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::SystemParams;
    use crate::util::Rng;

    fn topo() -> Topology {
        Topology::generate(&SystemParams::default(), &mut Rng::new(9))
    }

    #[test]
    fn features_normalized_to_unit_range() {
        let t = topo();
        let sched: Vec<usize> = (0..50).collect();
        let ef = build_features(&t, &sched);
        assert_eq!(ef.h, 50);
        assert_eq!(ef.f, 8);
        assert!(ef.feats.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // every column must hit both 0 and 1 (true min-max)
        for c in 0..ef.f {
            let col: Vec<f32> = (0..50).map(|t| ef.feats[t * 8 + c]).collect();
            let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(lo.abs() < 1e-6, "col {c} min {lo}");
            assert!((hi - 1.0).abs() < 1e-6, "col {c} max {hi}");
        }
    }

    #[test]
    fn constant_column_maps_to_half() {
        let t = topo();
        // single device: all columns degenerate
        let ef = build_features(&t, &[3]);
        assert!(ef.feats.iter().all(|&v| (v - 0.5).abs() < 1e-6));
    }

    #[test]
    fn pad_preserves_prefix() {
        let t = topo();
        let ef = build_features(&t, &[1, 2, 3]);
        let padded = ef.pad_to(10);
        assert_eq!(padded.h, 10);
        assert_eq!(&padded.feats[..3 * 8], &ef.feats[..]);
        assert!(padded.feats[3 * 8..].iter().all(|&v| v == 0.0));
    }
}
