//! Algorithm 5 — training the D³QN device-assignment agent.
//!
//! Both the control flow AND the numerics live in Rust now: Q-values,
//! double-DQN targets, the BiLSTM BPTT backward and Adam all run through
//! the [`Backend`] trait — [`crate::runtime::NativeBackend`] executes them
//! artifact-free (`runtime/native/{dqn,adam}.rs`), while a pjrt-feature
//! build can point the same loop at the `dqn_q_all_h<H>` / `dqn_train` AOT
//! artifacts as a parity oracle. Per episode:
//!
//! 1. generate a random deployment (Table I ranges) of H devices;
//! 2. run HFEL to obtain the expert assignment pattern Ψ̂ (the reward
//!    oracle, eq. 26);
//! 3. ONE `dqn_q_all` call yields Q(s_t, ·) for every slot (the state is
//!    position-indexed, see python/compile/dqn.py); actions are ε-greedy;
//! 4. push the H transitions; after each slot, one
//!    [`Backend::dqn_train_step`] on a uniform minibatch; sync the target
//!    net every J steps.
//!
//! Everything stochastic draws from the trainer's single `Rng` stream, so
//! a `(DqnTrainConfig, seed)` pair reproduces the episode rewards and the
//! final θ bit-for-bit regardless of thread count — the property the
//! determinism tests and the fig5 CI diff pin.
//!
//! Departures from the paper, recorded in DESIGN.md §5/§8: ε-greedy
//! exploration is added (Algorithm 5 line 9 is pure argmax, which never
//! explores non-greedy actions and cannot estimate their Q-values), and
//! the default network is smaller than the paper's 256-unit BiLSTM
//! (CPU wall-clock; `NativeBackend::with_dqn` restores any width).

use std::rc::Rc;

use super::episode::build_features;
use super::replay::{ReplayBuffer, Transition};
use crate::assignment::hfel::Hfel;
use crate::model::{init_params, Init};
use crate::runtime::{Backend, DqnBatch, DqnTrainState};
use crate::system::{SystemParams, Topology};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct DqnTrainConfig {
    pub episodes: usize,
    pub gamma: f32,
    /// Target-network sync interval J (steps).
    pub target_sync: usize,
    pub eps_start: f64,
    pub eps_end: f64,
    /// Episodes over which ε decays linearly.
    pub eps_decay_episodes: usize,
    pub buffer_cap: usize,
    /// HFEL exchange iterations for the reward oracle.
    pub hfel_exchange: usize,
    /// Run a gradient step every k-th time slot (paper: every slot; the
    /// default 2 halves wall-clock with indistinguishable curves).
    pub train_every: usize,
    pub seed: u64,
    /// Episode horizon H (devices per training deployment). `None` uses
    /// the backend's `consts.train_horizon`; the native backend accepts
    /// any value, PJRT only lowered horizons.
    pub horizon: Option<usize>,
    /// System parameter ranges for the random episode deployments.
    pub system: SystemParams,
}

impl Default for DqnTrainConfig {
    fn default() -> Self {
        DqnTrainConfig {
            episodes: 300,
            gamma: 0.99,
            target_sync: 100,
            eps_start: 0.8,
            eps_end: 0.02,
            eps_decay_episodes: 50,
            buffer_cap: 20_000,
            hfel_exchange: 150,
            train_every: 2,
            seed: 0,
            horizon: None,
            system: SystemParams::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Total reward per episode (max = H, i.e. full HFEL agreement).
    pub episode_rewards: Vec<f64>,
    /// TD loss per train step.
    pub losses: Vec<f32>,
    pub theta: Vec<f32>,
    /// Fraction of HFEL-matching actions per episode.
    pub match_rate: Vec<f64>,
}

pub struct DqnTrainer<'e> {
    backend: &'e dyn Backend,
    pub cfg: DqnTrainConfig,
    pub state: DqnTrainState,
    replay: ReplayBuffer,
    rng: Rng,
}

impl<'e> DqnTrainer<'e> {
    pub fn new(backend: &'e dyn Backend, cfg: DqnTrainConfig) -> anyhow::Result<Self> {
        let info = backend.manifest().model("dqn")?.clone();
        let mut rng = Rng::new(cfg.seed ^ 0xD3_00_00);
        let theta = init_params(&info, Init::GlorotUniform, &mut rng);
        Ok(DqnTrainer {
            backend,
            state: DqnTrainState::fresh(theta),
            replay: ReplayBuffer::new(cfg.buffer_cap),
            rng,
            cfg,
        })
    }

    /// The online network's current flat parameters.
    pub fn theta(&self) -> &[f32] {
        &self.state.theta
    }

    /// The episode horizon this configuration trains at.
    pub fn horizon(&self) -> usize {
        self.cfg
            .horizon
            .unwrap_or(self.backend.manifest().consts.train_horizon)
    }

    fn epsilon(&self, episode: usize) -> f64 {
        let c = &self.cfg;
        if episode >= c.eps_decay_episodes {
            c.eps_end
        } else {
            c.eps_start
                + (c.eps_end - c.eps_start) * episode as f64
                    / c.eps_decay_episodes as f64
        }
    }

    /// Q(s_t, ·) for all t of one episode: a single backend dispatch.
    pub fn q_all(&self, feats: &[f32], h: usize) -> anyhow::Result<Vec<f32>> {
        self.backend.dqn_q_all(&self.state.theta, feats, h)
    }

    fn train_step(&mut self, h: usize) -> anyhow::Result<f32> {
        let c = self.backend.manifest().consts.clone();
        let batch = self.replay.sample(c.o, h * c.feat, &mut self.rng);
        let loss = self.backend.dqn_train_step(
            &mut self.state,
            &DqnBatch {
                feats: &batch.feats,
                t: &batch.t,
                action: &batch.action,
                reward: &batch.reward,
                done: &batch.done,
                o: c.o,
                h,
            },
            self.cfg.gamma,
        )?;
        if (self.state.step as usize) % self.cfg.target_sync == 0 {
            self.state.sync_target();
        }
        Ok(loss)
    }

    /// Run Algorithm 5. `progress(episode, avg_reward_window)` is called
    /// once per episode (Fig. 5's y-axis is a 50-episode moving average).
    pub fn train(
        &mut self,
        mut progress: impl FnMut(usize, f64),
    ) -> anyhow::Result<TrainResult> {
        let consts = self.backend.manifest().consts.clone();
        let h = self.horizon();
        let m = consts.n_edges;
        let o = consts.o;
        anyhow::ensure!(h > 0, "dqn training horizon must be positive");
        let mut episode_rewards = Vec::with_capacity(self.cfg.episodes);
        let mut match_rate = Vec::with_capacity(self.cfg.episodes);
        let mut losses = Vec::new();

        let mut sys = self.cfg.system.clone();
        sys.n_devices = h; // an episode deploys exactly H devices

        for ep in 0..self.cfg.episodes {
            // Alg.5 L4: random deployment within Table I ranges
            let mut topo_rng = self.rng.fork(ep as u64);
            let topo = Topology::generate(&sys, &mut topo_rng);
            let scheduled: Vec<usize> = (0..h).collect();

            // Alg.5 L5: expert labels via HFEL
            let mut hfel = Hfel::new(self.cfg.hfel_exchange, self.cfg.seed ^ ep as u64);
            let labels = hfel.run(&topo, &scheduled);
            let label_index = labels.edge_index();
            let label_of: Vec<usize> = scheduled
                .iter()
                .map(|&n| label_index.edge_of(n).expect("hfel assigns everyone"))
                .collect();

            let ef = build_features(&topo, &scheduled);
            let q = self.q_all(&ef.feats, h)?;
            let feats_rc = Rc::new(ef.feats.clone());
            let eps = self.epsilon(ep);

            let mut total_r = 0.0f64;
            let mut matches = 0usize;
            for t in 0..h {
                let greedy = crate::util::stats::argmax_f32(&q[t * m..(t + 1) * m])
                    .unwrap();
                let action = if self.rng.f64() < eps {
                    self.rng.below(m)
                } else {
                    greedy
                };
                let reward = if action == label_of[t] { 1.0f32 } else { -1.0 };
                if action == label_of[t] {
                    matches += 1;
                }
                total_r += reward as f64;
                self.replay.push(Transition {
                    feats: feats_rc.clone(),
                    t: t as i32,
                    action: action as i32,
                    reward,
                    done: if t == h - 1 { 1.0 } else { 0.0 },
                });
                // Alg.5 L12-15: gradient step every `train_every` slots
                if self.replay.len() > o && t % self.cfg.train_every == 0 {
                    losses.push(self.train_step(h)?);
                }
            }
            episode_rewards.push(total_r);
            match_rate.push(matches as f64 / h as f64);
            let w = episode_rewards.len().min(50);
            let avg =
                episode_rewards[episode_rewards.len() - w..].iter().sum::<f64>() / w as f64;
            progress(ep, avg);
        }

        Ok(TrainResult {
            episode_rewards,
            losses,
            theta: self.state.theta.clone(),
            match_rate,
        })
    }
}
