//! Replay buffer Ω for D³QN training (§V-B).
//!
//! A transition references its episode's feature matrix via `Rc` — the
//! state is `(episode features, t)`, so storing the matrix once per episode
//! instead of twice per transition cuts memory ~100×.

use std::rc::Rc;

use crate::util::Rng;

#[derive(Clone)]
pub struct Transition {
    /// Shared `(H, F)` episode feature matrix.
    pub feats: Rc<Vec<f32>>,
    pub t: i32,
    pub action: i32,
    pub reward: f32,
    /// 1.0 when `t` is the last slot of the episode.
    pub done: f32,
}

/// Fixed-capacity ring buffer with uniform sampling.
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    cap: usize,
    next: usize,
}

/// A sampled minibatch in the flat layout the `dqn_train` artifact expects.
pub struct Batch {
    /// `(O, H, F)` flattened.
    pub feats: Vec<f32>,
    pub t: Vec<i32>,
    pub action: Vec<i32>,
    pub reward: Vec<f32>,
    pub done: Vec<f32>,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        ReplayBuffer { buf: Vec::with_capacity(cap), cap, next: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, tr: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(tr);
        } else {
            self.buf[self.next] = tr;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Uniformly sample `o` transitions (with replacement) into the flat
    /// batch layout. `hf` = H*F elements per episode matrix.
    pub fn sample(&self, o: usize, hf: usize, rng: &mut Rng) -> Batch {
        assert!(!self.buf.is_empty(), "sampling from empty replay buffer");
        let mut b = Batch {
            feats: Vec::with_capacity(o * hf),
            t: Vec::with_capacity(o),
            action: Vec::with_capacity(o),
            reward: Vec::with_capacity(o),
            done: Vec::with_capacity(o),
        };
        for _ in 0..o {
            let tr = &self.buf[rng.below(self.buf.len())];
            debug_assert_eq!(tr.feats.len(), hf);
            b.feats.extend_from_slice(&tr.feats);
            b.t.push(tr.t);
            b.action.push(tr.action);
            b.reward.push(tr.reward);
            b.done.push(tr.done);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(t: i32) -> Transition {
        Transition {
            feats: Rc::new(vec![t as f32; 6]),
            t,
            action: t % 3,
            reward: 1.0,
            done: 0.0,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(tr(i));
        }
        assert_eq!(rb.len(), 3);
        let ts: Vec<i32> = rb.buf.iter().map(|x| x.t).collect();
        // slots: [3, 4, 2]
        assert!(ts.contains(&2) && ts.contains(&3) && ts.contains(&4));
        assert!(!ts.contains(&0) && !ts.contains(&1));
    }

    #[test]
    fn sample_layout() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..4 {
            rb.push(tr(i));
        }
        let b = rb.sample(8, 6, &mut Rng::new(1));
        assert_eq!(b.feats.len(), 8 * 6);
        assert_eq!(b.t.len(), 8);
        // every sampled feats block matches its t marker
        for i in 0..8 {
            assert_eq!(b.feats[i * 6], b.t[i] as f32);
        }
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(2);
        rb.sample(1, 6, &mut Rng::new(0));
    }
}
