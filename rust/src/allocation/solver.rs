//! Native convex solver for the per-edge resource allocation problem (27):
//!
//! ```text
//!   min_{b, f}  E_m + λ·T_m
//!   s.t.        Σ_n b_n ≤ B_m,   0 < f_n ≤ f_max
//! ```
//!
//! The paper solves this with CVXPY; we solve the same convex program
//! natively (DESIGN.md §5) with an epigraph decomposition:
//!
//! * For a fixed per-edge-iteration round time τ, the optimal CPU frequency
//!   is closed-form: run exactly as slow as the deadline allows,
//!   `f_n* = c_n / (τ − T_com(b_n))` (energy ∝ f², idling is free), which
//!   is feasible iff `T_com(b_n) ≤ τ − c_n/f_max`.
//! * The remaining bandwidth subproblem `min Σ_n E_n(b_n; τ)` over the
//!   simplex `{Σ b = B_m, b ≥ b_min(τ)}` is smooth and convex; we solve it
//!   with projected gradient descent + backtracking, warm-started across
//!   τ evaluations.
//! * The outer 1-D function g(τ) is convex (partial minimization of a
//!   jointly convex program), minimized by golden-section search over a
//!   bracket found by feasibility bisection + exponential expansion.
//!
//! Correctness is pinned against a brute-force grid oracle in
//! `bruteforce.rs` (tests assert ≤1% relative objective gap).

use crate::system::cost::{cloud_cost, DeviceAlloc, EdgeCost};
use crate::system::Topology;

const LN2: f64 = std::f64::consts::LN_2;

/// Precomputed per-device link/compute constants for one (device, edge).
#[derive(Clone, Copy, Debug)]
struct Link {
    /// γ = ḡ·p / N0, in Hz (SNR numerator per unit bandwidth).
    gamma: f64,
    /// Transmit power, W.
    p: f64,
    /// Total cycles per edge iteration: c = L·u_n·D_n.
    c: f64,
    f_max: f64,
}

impl Link {
    /// Uplink rate (eq. 6) in bit/s.
    fn rate(&self, b: f64) -> f64 {
        if b <= 0.0 {
            0.0
        } else {
            b * (1.0 + self.gamma / b).ln() / LN2
        }
    }

    /// d rate / d b — positive, decreasing.
    fn rate_deriv(&self, b: f64) -> f64 {
        let x = self.gamma / b;
        ((1.0 + x).ln() - x / (1.0 + x)) / LN2
    }

    /// Asymptotic rate cap as b → ∞: γ/ln2.
    fn rate_cap(&self) -> f64 {
        self.gamma / LN2
    }
}

/// Tunables; defaults give ≤0.3% objective gap vs the brute-force oracle.
#[derive(Clone, Debug)]
pub struct SolverOpts {
    pub tau_iters: usize,
    pub pg_iters: usize,
    pub pg_iters_warm: usize,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts { tau_iters: 40, pg_iters: 120, pg_iters_warm: 30 }
    }
}

impl SolverOpts {
    /// Low-precision preset for search-internal evaluations (HFEL tries
    /// hundreds of candidate moves and only needs the objective ORDERING;
    /// final reported costs always use the default precision).
    pub fn fast() -> Self {
        SolverOpts { tau_iters: 12, pg_iters: 30, pg_iters_warm: 8 }
    }
}

/// Result of one per-edge solve.
#[derive(Clone, Debug)]
pub struct AllocSolution {
    /// Device order matches the `devices` argument of [`solve_edge`].
    pub allocs: Vec<DeviceAlloc>,
    pub cost: EdgeCost,
    /// Per-edge objective `E_m + λ·T_m` (problem 27).
    pub objective: f64,
}

/// Minimum bandwidth for device `l` to meet round time τ (∞ if impossible).
fn b_min(l: &Link, z_bits: f64, tau: f64) -> f64 {
    let slack = tau - l.c / l.f_max;
    if slack <= 0.0 {
        return f64::INFINITY;
    }
    let need_rate = z_bits / slack;
    if need_rate >= l.rate_cap() * 0.999_999 {
        return f64::INFINITY; // Shannon cap: no bandwidth is enough
    }
    // rate(b) is increasing in b: bisect
    let mut lo = 1e-3;
    let mut hi = 1.0;
    while l.rate(hi) < need_rate {
        hi *= 2.0;
        if hi > 1e15 {
            return f64::INFINITY;
        }
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if l.rate(mid) < need_rate {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

/// Energy of device `l` at bandwidth b under deadline τ (optimal f).
fn device_energy(l: &Link, z_bits: f64, alpha: f64, tau: f64, b: f64) -> f64 {
    let t_com = z_bits / l.rate(b);
    let slack = tau - t_com;
    debug_assert!(slack > 0.0);
    let f = (l.c / slack).min(l.f_max);
    0.5 * alpha * l.c * f * f + l.p * t_com
}

/// dE/db at bandwidth b (negative: more bandwidth always helps).
fn device_energy_deriv(l: &Link, z_bits: f64, alpha: f64, tau: f64, b: f64) -> f64 {
    let r = l.rate(b);
    let t_com = z_bits / r;
    let slack = tau - t_com;
    let dt_db = -z_bits * l.rate_deriv(b) / (r * r);
    let f = l.c / slack;
    let de_cmp_dt = if f < l.f_max {
        // f* = c/slack ⇒ dE_cmp/dT_com = α·c³/slack³
        alpha * l.c * l.c * l.c / (slack * slack * slack)
    } else {
        0.0 // f pinned at f_max: compute energy insensitive to b
    };
    dt_db * (l.p + de_cmp_dt)
}

/// Euclidean projection onto `{x : Σx = total, x ≥ lo}` (lo feasible).
/// Standard O(n log n) water-filling.
fn project_simplex_lb(x: &mut [f64], lo: &[f64], total: f64) {
    let n = x.len();
    // shift: y = x - lo, project y onto {Σy = s, y ≥ 0}
    let s = total - lo.iter().sum::<f64>();
    debug_assert!(s >= -1e-9);
    let mut y: Vec<f64> = x.iter().zip(lo).map(|(&xi, &li)| xi - li).collect();
    let mut sorted = y.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cum = 0.0;
    let mut theta = 0.0;
    let mut k = 0;
    for (i, &v) in sorted.iter().enumerate() {
        cum += v;
        let t = (cum - s) / (i + 1) as f64;
        if v - t > 0.0 {
            theta = t;
            k = i + 1;
        }
    }
    let _ = k;
    for yi in y.iter_mut() {
        *yi = (*yi - theta).max(0.0);
    }
    for i in 0..n {
        x[i] = lo[i] + y[i];
    }
}

/// Inner problem: minimize Σ E_n(b_n; τ) over the bandwidth simplex.
/// `b` is the warm start (projected to feasibility first).
fn solve_bandwidth(
    links: &[Link],
    z_bits: f64,
    alpha: f64,
    tau: f64,
    b_total: f64,
    b: &mut [f64],
    iters: usize,
) -> Option<f64> {
    let n = links.len();
    let lo: Vec<f64> = links.iter().map(|l| b_min(l, z_bits, tau)).collect();
    if lo.iter().any(|v| !v.is_finite()) {
        return None;
    }
    let lo_sum: f64 = lo.iter().sum();
    if lo_sum > b_total {
        return None; // τ infeasible: even minimal bandwidths overflow B_m
    }
    project_simplex_lb(b, &lo, b_total);

    let energy = |b: &[f64]| -> f64 {
        links
            .iter()
            .zip(b)
            .map(|(l, &bi)| device_energy(l, z_bits, alpha, tau, bi))
            .sum()
    };

    let mut e_cur = energy(b);
    // normalized step: bandwidths are O(B_m); gradients O(1e-6..1e-3)
    let mut step = b_total * 0.25;
    let mut grad = vec![0.0f64; n];
    for _ in 0..iters {
        let gnorm = {
            let mut s = 0.0;
            for i in 0..n {
                grad[i] = device_energy_deriv(&links[i], z_bits, alpha, tau, b[i]);
                s += grad[i] * grad[i];
            }
            s.sqrt()
        };
        if gnorm < 1e-18 {
            break;
        }
        let mut trial: Vec<f64> = (0..n)
            .map(|i| b[i] - step * grad[i] / gnorm)
            .collect();
        project_simplex_lb(&mut trial, &lo, b_total);
        let e_trial = energy(&trial);
        if e_trial < e_cur {
            b.copy_from_slice(&trial);
            let improved = e_cur - e_trial;
            e_cur = e_trial;
            step *= 1.3;
            if improved < e_cur.abs() * 1e-10 + 1e-18 {
                break;
            }
        } else {
            step *= 0.5;
            if step < b_total * 1e-9 {
                break;
            }
        }
    }
    Some(e_cur)
}

/// Solve problem (27) for edge `m` over `devices`. Empty device set yields
/// a zero-cost solution (the edge sits out this iteration).
pub fn solve_edge(
    topo: &Topology,
    m: usize,
    devices: &[usize],
    lambda: f64,
    opts: &SolverOpts,
) -> AllocSolution {
    if devices.is_empty() {
        return AllocSolution {
            allocs: vec![],
            cost: EdgeCost { t: 0.0, e: 0.0 },
            objective: 0.0,
        };
    }
    let p = &topo.params;
    let z = p.model_bits;
    let alpha = p.alpha;
    let q = p.edge_iters as f64;
    let b_total = topo.edges[m].bandwidth_hz;
    let n0 = topo.channel.noise_w_per_hz;

    let links: Vec<Link> = devices
        .iter()
        .map(|&n| {
            let d = topo.device(n);
            Link {
                gamma: topo.gain(n, m) * d.tx_power_w / n0,
                p: d.tx_power_w,
                c: p.local_iters as f64 * d.cycles_per_sample * d.num_samples as f64,
                f_max: d.max_freq_hz,
            }
        })
        .collect();

    // τ lower bound: every device with ALL the bandwidth at f_max.
    let tau_floor = links
        .iter()
        .map(|l| l.c / l.f_max + z / l.rate(b_total))
        .fold(0.0f64, f64::max);
    // Feasible upper start: equal split at f_max.
    let nb = b_total / links.len() as f64;
    let tau_feas = links
        .iter()
        .map(|l| l.c / l.f_max + z / l.rate(nb))
        .fold(0.0f64, f64::max)
        * 1.0001;

    // g(τ): minimized Σ E + λ·τ (Q factors out of the argmin; reapplied in
    // the reported cost). Returns +∞ when τ is infeasible.
    let mut warm: Vec<f64> = vec![nb; links.len()];
    let g = |tau: f64, warm: &mut Vec<f64>, iters: usize| -> f64 {
        match solve_bandwidth(&links, z, alpha, tau, b_total, warm, iters) {
            Some(e) => e + lambda * tau,
            None => f64::INFINITY,
        }
    };

    // Bracket: find feasible lower edge by bisection on feasibility.
    let mut lo = tau_floor;
    let mut hi = tau_feas;
    {
        let mut trial = warm.clone();
        for _ in 0..30 {
            let mid = 0.5 * (lo + hi);
            let mut w = trial.clone();
            if g(mid, &mut w, opts.pg_iters_warm).is_finite() {
                hi = mid;
                trial = w;
            } else {
                lo = mid;
            }
        }
    }
    let tau_lo = hi; // smallest known-feasible τ

    // Expand upward while g still decreases (energy savings from slower f).
    let mut tau_hi = tau_lo.max(tau_feas);
    {
        let mut g_hi = g(tau_hi, &mut warm, opts.pg_iters);
        loop {
            let cand = tau_hi * 1.8;
            let mut w = warm.clone();
            let g_cand = g(cand, &mut w, opts.pg_iters_warm);
            if g_cand < g_hi {
                tau_hi = cand;
                g_hi = g_cand;
                warm = w;
            } else {
                break;
            }
            if tau_hi > tau_lo * 1e6 {
                break;
            }
        }
        tau_hi *= 1.8; // one margin step past the turn
    }

    // Golden-section on [tau_lo, tau_hi].
    let gr = (5f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut bb) = (tau_lo, tau_hi);
    let mut x1 = bb - gr * (bb - a);
    let mut x2 = a + gr * (bb - a);
    let mut f1 = g(x1, &mut warm, opts.pg_iters);
    let mut f2 = g(x2, &mut warm, opts.pg_iters_warm);
    for _ in 0..opts.tau_iters {
        if f1 <= f2 {
            bb = x2;
            x2 = x1;
            f2 = f1;
            x1 = bb - gr * (bb - a);
            f1 = g(x1, &mut warm, opts.pg_iters_warm);
        } else {
            a = x1;
            x1 = x2;
            f1 = f2;
            x2 = a + gr * (bb - a);
            f2 = g(x2, &mut warm, opts.pg_iters_warm);
        }
        if (bb - a) < 1e-4 * bb {
            break;
        }
    }
    let tau_star = if f1 <= f2 { x1 } else { x2 };
    let _ = g(tau_star, &mut warm, opts.pg_iters);

    // Materialize the final allocation.
    let allocs: Vec<DeviceAlloc> = links
        .iter()
        .zip(&warm)
        .map(|(l, &bi)| {
            let t_com = z / l.rate(bi);
            let f = (l.c / (tau_star - t_com)).clamp(0.0, l.f_max);
            DeviceAlloc { bandwidth_hz: bi, freq_hz: f }
        })
        .collect();

    let (t_cloud, e_cloud) = cloud_cost(topo, m);
    let e_sum: f64 = links
        .iter()
        .zip(&warm)
        .map(|(l, &bi)| device_energy(l, z, alpha, tau_star, bi))
        .sum();
    // actual max round time (≤ τ*, devices may beat the deadline at f_max)
    let t_round = links
        .iter()
        .zip(&allocs)
        .map(|(l, al)| l.c / al.freq_hz + z / l.rate(al.bandwidth_hz))
        .fold(0.0f64, f64::max);
    let cost = EdgeCost { t: q * t_round + t_cloud, e: q * e_sum + e_cloud };
    AllocSolution { allocs, cost, objective: cost.e + lambda * cost.t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::cost::edge_cost;
    use crate::system::{SystemParams, Topology};
    use crate::util::Rng;

    fn topo() -> Topology {
        Topology::generate(&SystemParams::default(), &mut Rng::new(3))
    }

    #[test]
    fn empty_device_set_is_free() {
        let t = topo();
        let s = solve_edge(&t, 0, &[], 1.0, &SolverOpts::default());
        assert_eq!(s.objective, 0.0);
        assert!(s.allocs.is_empty());
    }

    #[test]
    fn constraints_respected() {
        let t = topo();
        let devices = [0, 5, 11, 17, 23];
        let s = solve_edge(&t, 1, &devices, 1.0, &SolverOpts::default());
        let b_sum: f64 = s.allocs.iter().map(|a| a.bandwidth_hz).sum();
        assert!(b_sum <= t.edges[1].bandwidth_hz * 1.000001, "{b_sum}");
        for (a, &n) in s.allocs.iter().zip(&devices) {
            assert!(a.bandwidth_hz > 0.0);
            assert!(a.freq_hz > 0.0);
            assert!(a.freq_hz <= t.device(n).max_freq_hz * 1.000001);
        }
    }

    #[test]
    fn objective_consistent_with_cost_model() {
        // The solver's reported cost must equal the cost model's evaluation
        // of its own allocation.
        let t = topo();
        let devices = [2, 7, 31];
        let s = solve_edge(&t, 0, &devices, 1.0, &SolverOpts::default());
        let group: Vec<(usize, DeviceAlloc)> = devices
            .iter()
            .cloned()
            .zip(s.allocs.iter().cloned())
            .collect();
        let ec = edge_cost(&t, 0, &group);
        assert!((ec.t - s.cost.t).abs() / s.cost.t < 1e-6, "{} vs {}", ec.t, s.cost.t);
        assert!((ec.e - s.cost.e).abs() / s.cost.e < 1e-6, "{} vs {}", ec.e, s.cost.e);
    }

    #[test]
    fn beats_naive_equal_split() {
        let t = topo();
        let devices = [1, 4, 9, 16, 25, 36];
        let s = solve_edge(&t, 2, &devices, 1.0, &SolverOpts::default());
        // naive: equal bandwidth, f_max
        let nb = t.edges[2].bandwidth_hz / devices.len() as f64;
        let naive: Vec<(usize, DeviceAlloc)> = devices
            .iter()
            .map(|&n| {
                (n, DeviceAlloc { bandwidth_hz: nb, freq_hz: t.device(n).max_freq_hz })
            })
            .collect();
        let ec = edge_cost(&t, 2, &naive);
        let naive_obj = ec.e + ec.t;
        assert!(
            s.objective <= naive_obj * 1.0001,
            "solver {} vs naive {}",
            s.objective,
            naive_obj
        );
    }

    #[test]
    fn more_lambda_means_less_time() {
        let t = topo();
        let devices = [3, 8, 13];
        let s_lo = solve_edge(&t, 0, &devices, 0.1, &SolverOpts::default());
        let s_hi = solve_edge(&t, 0, &devices, 100.0, &SolverOpts::default());
        assert!(s_hi.cost.t <= s_lo.cost.t * 1.01, "{} vs {}", s_hi.cost.t, s_lo.cost.t);
        assert!(s_lo.cost.e <= s_hi.cost.e * 1.01, "{} vs {}", s_lo.cost.e, s_hi.cost.e);
    }

    #[test]
    fn single_device_gets_all_bandwidth() {
        let t = topo();
        let s = solve_edge(&t, 0, &[42], 1.0, &SolverOpts::default());
        assert!(
            (s.allocs[0].bandwidth_hz - t.edges[0].bandwidth_hz).abs()
                / t.edges[0].bandwidth_hz
                < 1e-3
        );
    }

    #[test]
    fn projection_respects_bounds_and_sum() {
        let mut x = vec![0.5, 0.1, 0.9];
        let lo = vec![0.2, 0.2, 0.2];
        project_simplex_lb(&mut x, &lo, 1.0);
        let s: f64 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(x.iter().zip(&lo).all(|(&xi, &li)| xi >= li - 1e-12));
    }

    #[test]
    fn projection_identity_when_feasible() {
        let mut x = vec![0.3, 0.3, 0.4];
        let lo = vec![0.0, 0.0, 0.0];
        project_simplex_lb(&mut x, &lo, 1.0);
        assert!((x[0] - 0.3).abs() < 1e-9);
        assert!((x[2] - 0.4).abs() < 1e-9);
    }
}
