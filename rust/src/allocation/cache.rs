//! Incremental evaluation of the one-round objective (17).
//!
//! The objective decomposes per edge: `E = Σ_m E_m`, `T = max_m T_m`, and
//! a candidate move/swap touches at most two edges — yet the legacy HFEL
//! and greedy paths cloned whole groups and re-derived per-edge state for
//! every candidate. `CostCache` keeps the committed per-edge solution
//! (objective, [`EdgeCost`], per-device [`DeviceCost`]s) and recomputes
//! only the *dirty* edges of a hypothetical or applied change, through a
//! reusable scratch buffer instead of per-candidate `Vec` clones.
//!
//! Two evaluation backends share the bookkeeping:
//!
//! * **solver** ([`CostCache::new_solver`]) — each edge's objective is the
//!   solved problem (27) via [`solve_edge`]; this is what HFEL and the
//!   greedy assigner search over (the separable surrogate
//!   Σ_m (E_m + λ·T_m)). Identical inputs give identical floats, so a
//!   cache-driven search accepts exactly the moves the legacy clone-based
//!   code accepted.
//! * **equal-split** ([`CostCache::new_equal_split`]) — `b_n = B_m/|g|`,
//!   `f_n = f^max` (the fixed allocation used for cost accounting at fleet
//!   scale, where 10³ solver runs per round would dominate); dirty-edge
//!   updates are O(|group|) evaluations of eqs. 4–12.
//!
//! From-scratch oracles: [`crate::assignment::evaluate`] (solver) and
//! [`crate::system::cost::iter_cost`] (fixed allocs) — the equivalence is
//! pinned by `tests/topo_scale.rs` after randomized move/swap sequences.

use super::solver::{solve_edge, SolverOpts};
use crate::system::cost::{cloud_cost, device_cost, DeviceAlloc, DeviceCost, EdgeCost, IterCost};
use crate::system::Topology;

enum Backend {
    Solver(SolverOpts),
    EqualSplit,
}

pub struct CostCache {
    lambda: f64,
    backend: Backend,
    /// Committed groups, one per edge (the cache owns its membership copy).
    members: Vec<Vec<usize>>,
    /// Per-edge surrogate objective `E_m + λ·T_m` of the committed state.
    obj: Vec<f64>,
    /// Per-edge eq. 13–14 inner terms of the committed state.
    cost: Vec<EdgeCost>,
    /// Per-device costs, parallel to `members[m]`.
    dcosts: Vec<Vec<DeviceCost>>,
    /// Reusable candidate-group buffer (replaces per-candidate clones).
    scratch: Vec<usize>,
}

impl CostCache {
    pub fn new_solver(lambda: f64, opts: SolverOpts) -> Self {
        Self::new(lambda, Backend::Solver(opts))
    }

    pub fn new_equal_split(lambda: f64) -> Self {
        Self::new(lambda, Backend::EqualSplit)
    }

    fn new(lambda: f64, backend: Backend) -> Self {
        CostCache {
            lambda,
            backend,
            members: vec![],
            obj: vec![],
            cost: vec![],
            dcosts: vec![],
            scratch: vec![],
        }
    }

    /// Full recompute from `groups` (adopts them as the committed state).
    pub fn reset(&mut self, topo: &Topology, groups: &[Vec<usize>]) {
        self.members = groups.to_vec();
        let m_count = self.members.len();
        self.obj = vec![0.0; m_count];
        self.cost = vec![EdgeCost::default(); m_count];
        self.dcosts = vec![Vec::new(); m_count];
        for m in 0..m_count {
            self.refresh_edge(topo, m);
        }
    }

    /// Evaluate one group under the configured backend.
    fn eval_group(
        &self,
        topo: &Topology,
        m: usize,
        group: &[usize],
    ) -> (f64, EdgeCost, Vec<DeviceCost>) {
        if group.is_empty() {
            return (0.0, EdgeCost::default(), vec![]);
        }
        match &self.backend {
            Backend::Solver(opts) => {
                let s = solve_edge(topo, m, group, self.lambda, opts);
                let dcosts = group
                    .iter()
                    .zip(&s.allocs)
                    .map(|(&n, &a)| device_cost(topo, n, m, a))
                    .collect();
                (s.objective, s.cost, dcosts)
            }
            Backend::EqualSplit => {
                let b = topo.edges[m].bandwidth_hz / group.len() as f64;
                let alloc = DeviceAlloc { bandwidth_hz: b, freq_hz: topo.fleet.max_freq_hz() };
                let q = topo.params.edge_iters as f64;
                let mut t_max = 0.0f64;
                let mut e_sum = 0.0f64;
                let dcosts: Vec<DeviceCost> = group
                    .iter()
                    .map(|&n| {
                        let c = device_cost(topo, n, m, alloc);
                        t_max = t_max.max(c.t_total());
                        e_sum += c.e_total();
                        c
                    })
                    .collect();
                let (t_cloud, e_cloud) = cloud_cost(topo, m);
                let ec = EdgeCost { t: q * t_max + t_cloud, e: q * e_sum + e_cloud };
                (ec.e + self.lambda * ec.t, ec, dcosts)
            }
        }
    }

    /// Recompute one dirty edge from its committed membership.
    fn refresh_edge(&mut self, topo: &Topology, m: usize) {
        let (obj, cost, dcosts) = self.eval_group(topo, m, &self.members[m]);
        self.obj[m] = obj;
        self.cost[m] = cost;
        self.dcosts[m] = dcosts;
    }

    pub fn n_edges(&self) -> usize {
        self.members.len()
    }

    pub fn members(&self, m: usize) -> &[usize] {
        &self.members[m]
    }

    /// Committed groups — e.g. to build the final [`crate::assignment::Assignment`].
    pub fn groups(&self) -> &[Vec<usize>] {
        &self.members
    }

    pub fn edge_objective(&self, m: usize) -> f64 {
        self.obj[m]
    }

    pub fn edge_cost(&self, m: usize) -> EdgeCost {
        self.cost[m]
    }

    /// Per-device costs of edge `m`'s committed solution, parallel to
    /// [`CostCache::members`].
    pub fn device_costs(&self, m: usize) -> &[DeviceCost] {
        &self.dcosts[m]
    }

    /// Separable surrogate Σ_m (E_m + λ·T_m) — HFEL's search total.
    pub fn surrogate_total(&self) -> f64 {
        self.obj.iter().sum()
    }

    /// True objective-(17) terms: straggler max over non-empty edges (an
    /// O(M) fold over cached per-edge values) + energy sum.
    pub fn iter_cost(&self) -> IterCost {
        let mut t = 0.0f64;
        let mut e = 0.0f64;
        for (m, g) in self.members.iter().enumerate() {
            if g.is_empty() {
                continue;
            }
            t = t.max(self.cost[m].t);
            e += self.cost[m].e;
        }
        IterCost { t, e }
    }

    /// Objective of edge `m` serving exactly `group` (no state change) —
    /// the exact oracle's leaf evaluator. A pure function of
    /// `(topo, m, group)` including member order, which is why the oracle
    /// canonicalizes groups into scheduled order before calling.
    pub fn eval_group_objective(&mut self, topo: &Topology, m: usize, group: &[usize]) -> f64 {
        self.eval_group(topo, m, group).0
    }

    /// Objective of edge `m` with `dev` removed (no state change).
    pub fn eval_remove(&mut self, topo: &Topology, m: usize, dev: usize) -> f64 {
        self.scratch.clear();
        self.scratch.extend(self.members[m].iter().copied().filter(|&d| d != dev));
        self.eval_group(topo, m, &self.scratch).0
    }

    /// Objective of edge `m` with `dev` appended (no state change).
    pub fn eval_add(&mut self, topo: &Topology, m: usize, dev: usize) -> f64 {
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.members[m]);
        self.scratch.push(dev);
        self.eval_group(topo, m, &self.scratch).0
    }

    /// Objective of edge `m` with `out` replaced by `inn` in place (the
    /// exchange-candidate shape: position preserved; no state change).
    pub fn eval_swap_in_place(
        &mut self,
        topo: &Topology,
        m: usize,
        out: usize,
        inn: usize,
    ) -> f64 {
        self.scratch.clear();
        self.scratch.extend(
            self.members[m].iter().map(|&d| if d == out { inn } else { d }),
        );
        self.eval_group(topo, m, &self.scratch).0
    }

    /// Commit a transfer `dev: src → dst`; both edges become dirty and are
    /// recomputed (membership order matches the legacy mutation:
    /// `retain` on src, `push` on dst — so solver inputs are identical).
    pub fn apply_move(&mut self, topo: &Topology, src: usize, dst: usize, dev: usize) {
        self.members[src].retain(|&d| d != dev);
        self.members[dst].push(dev);
        self.refresh_edge(topo, src);
        self.refresh_edge(topo, dst);
    }

    /// Commit an exchange `d1 ∈ e1 ↔ d2 ∈ e2` (in-place replacement).
    pub fn apply_swap(&mut self, topo: &Topology, e1: usize, d1: usize, e2: usize, d2: usize) {
        for d in self.members[e1].iter_mut() {
            if *d == d1 {
                *d = d2;
            }
        }
        for d in self.members[e2].iter_mut() {
            if *d == d2 && *d != d1 {
                *d = d1;
            }
        }
        self.refresh_edge(topo, e1);
        self.refresh_edge(topo, e2);
    }

    /// Commit appending `dev` to edge `m` (the greedy-constructive shape).
    pub fn apply_add(&mut self, topo: &Topology, m: usize, dev: usize) {
        self.members[m].push(dev);
        self.refresh_edge(topo, m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::cost::iter_cost;
    use crate::system::SystemParams;
    use crate::util::Rng;

    fn topo() -> Topology {
        Topology::generate(&SystemParams::default(), &mut Rng::new(9))
    }

    fn groups() -> Vec<Vec<usize>> {
        vec![vec![0, 1, 2], vec![3, 4], vec![5], vec![], vec![6, 7]]
    }

    #[test]
    fn equal_split_matches_from_scratch_iter_cost() {
        let t = topo();
        let mut c = CostCache::new_equal_split(t.params.lambda);
        c.reset(&t, &groups());
        let reference: Vec<Vec<(usize, DeviceAlloc)>> = groups()
            .iter()
            .enumerate()
            .map(|(m, g)| {
                let b = t.edges[m].bandwidth_hz / g.len().max(1) as f64;
                g.iter()
                    .map(|&n| {
                        (n, DeviceAlloc { bandwidth_hz: b, freq_hz: t.fleet.max_freq_hz() })
                    })
                    .collect()
            })
            .collect();
        let want = iter_cost(&t, &reference);
        let got = c.iter_cost();
        assert_eq!(got.t, want.t);
        assert_eq!(got.e, want.e);
    }

    #[test]
    fn apply_move_equals_reset_from_scratch() {
        let t = topo();
        let mut c = CostCache::new_solver(t.params.lambda, SolverOpts::fast());
        c.reset(&t, &groups());
        c.apply_move(&t, 0, 3, 1);
        let mut fresh = CostCache::new_solver(t.params.lambda, SolverOpts::fast());
        fresh.reset(&t, c.groups().to_vec().as_slice());
        assert_eq!(c.surrogate_total(), fresh.surrogate_total());
        assert_eq!(c.iter_cost().t, fresh.iter_cost().t);
        assert_eq!(c.members(3), &[3, 4, 1]);
    }

    #[test]
    fn eval_does_not_mutate_committed_state() {
        let t = topo();
        let mut c = CostCache::new_solver(t.params.lambda, SolverOpts::fast());
        c.reset(&t, &groups());
        let before = c.surrogate_total();
        let _ = c.eval_add(&t, 2, 9);
        let _ = c.eval_remove(&t, 0, 1);
        let _ = c.eval_swap_in_place(&t, 1, 3, 9);
        assert_eq!(c.surrogate_total(), before);
        assert_eq!(c.members(0), &[0, 1, 2]);
    }

    #[test]
    fn device_costs_track_membership() {
        let t = topo();
        let mut c = CostCache::new_equal_split(t.params.lambda);
        c.reset(&t, &groups());
        assert_eq!(c.device_costs(0).len(), 3);
        c.apply_add(&t, 2, 9);
        assert_eq!(c.device_costs(2).len(), 2);
        assert!(c.device_costs(2)[1].t_total() > 0.0);
    }
}
