//! Exact branch-and-bound oracle for the joint assignment problem (17).
//!
//! The heuristics in `policy/` (HFEL search, greedy marginal-cost, D³QN)
//! only ever compare against each other; this module answers the question
//! none of them can: *how far from optimal is an assignment actually?*
//! It enumerates device→edge assignments with the per-edge convex solver
//! (`allocation/solver.rs`, reached through [`CostCache`]'s group
//! evaluator) as the leaf oracle, and prunes with an admissible
//! cheapest-marginal lower bound (DESIGN.md §12).
//!
//! Objective: the separable surrogate `F(A) = Σ_m (E_m + λ·T_m)` — the
//! same quantity [`CostCache::surrogate_total`] tracks and HFEL/greedy
//! search, so oracle objectives are directly comparable to every
//! heuristic's own search criterion.
//!
//! Determinism contract:
//! * devices are branched in **scheduled order** (slot i = i-th scheduled
//!   device), and every leaf/memo evaluation lists group members in that
//!   same order, so identical inputs produce bit-identical floats;
//! * the frontier is a best-first heap ordered by `(bound, node_id)` with
//!   `f64::total_cmp` — smallest bound first, lower (earlier-created) id
//!   on ties — so the expansion sequence is a pure function of the cost
//!   table;
//! * budgets count expanded nodes, not wall time, by default. A wall-time
//!   limit is available for interactive use but intentionally **not**
//!   used by sweeps: it would make output depend on machine speed.
//!
//! Budget degradation: when the node budget is exhausted the solver
//! returns the best incumbent found so far (the root is seeded with a
//! greedy constructive pass, so an incumbent always exists) together with
//! the smallest open bound as a *proven* lower bound and `proven: false`.
//! Callers get a valid assignment plus an honest bracket instead of a
//! hang.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};
use std::time::Instant;

use crate::allocation::{CostCache, SolverOpts};
use crate::assignment::Assignment;
use crate::system::Topology;

/// Masks index scheduled slots, so the subsystem caps at one machine word
/// of devices. Larger cells fall back to heuristics (see `oracle?fallback=`).
pub const MAX_EXACT_DEVICES: usize = 64;

/// Relative pruning slack: the bound must beat the incumbent by more than
/// this margin before a subtree is discarded. The cheapest-marginal bound
/// is admissible for exactly supermodular cost tables (DESIGN.md §12);
/// the convex solver's numerics can violate supermodularity by ~1e-12 at
/// degenerate ties, and this slack keeps such noise from pruning the true
/// optimum. Costs only sharpen the proof, never the incumbent, so the
/// result is still exact — we merely expand a hair more.
const BOUND_SLACK: f64 = 1e-9;

/// Search budgets. `node_budget` bounds heap expansions (deterministic);
/// `time_budget_ms` is an optional wall-clock cap for interactive use.
#[derive(Clone, Debug)]
pub struct ExactOpts {
    pub node_budget: usize,
    pub time_budget_ms: Option<u64>,
}

impl Default for ExactOpts {
    fn default() -> Self {
        ExactOpts { node_budget: 100_000, time_budget_ms: None }
    }
}

/// Outcome of a branch-and-bound run over one scheduled set.
#[derive(Clone, Debug)]
pub struct ExactResult {
    /// Per-slot edge choice, parallel to the scheduled list.
    pub choices: Vec<usize>,
    /// Surrogate objective F of `choices` (exact leaf evaluation).
    pub objective: f64,
    /// Proven global lower bound on F*. Equals `objective` when `proven`.
    pub lower_bound: f64,
    /// True iff the search closed the whole tree within budget.
    pub proven: bool,
    /// Heap expansions performed (≤ `node_budget`).
    pub nodes_expanded: usize,
}

/// Pluggable cost table: the branch-and-bound mechanics only ever see
/// edge-subset costs through this trait. Production uses [`SolverCost`]
/// (convex solver + memo); unit tests and the stdlib-python mirror use a
/// tiny closed-form table so the full search trace can be pinned as
/// constants on both sides.
pub trait AssignCost {
    /// Number of scheduled devices (branching slots).
    fn n_slots(&self) -> usize;
    /// Number of edge servers.
    fn n_edges(&self) -> usize;
    /// Candidate edges of slot `s`, in deterministic (ascending) order.
    fn candidates(&self, s: usize) -> &[usize];
    /// Cost of edge `m` serving exactly the slots in `mask` (bit i = slot
    /// i). Must be a pure function of `(m, mask)`.
    fn group_cost(&mut self, m: usize, mask: u64) -> f64;
}

/// Production cost table: memoized `(edge, slot-mask)` solves through the
/// same [`CostCache`] group evaluator the heuristics use. Memoization is
/// what makes child bounds O(dirty edge): expanding a node re-prices only
/// the column of the edge whose mask changed — every other `(m, mask)`
/// lookup was already priced by an ancestor and hits the map.
pub struct SolverCost<'a> {
    topo: &'a Topology,
    scheduled: &'a [usize],
    cands: Vec<Vec<usize>>,
    cache: CostCache,
    memo: HashMap<(usize, u64), f64>,
    buf: Vec<usize>,
}

impl<'a> SolverCost<'a> {
    pub fn new(topo: &'a Topology, scheduled: &'a [usize], opts: &SolverOpts) -> Self {
        assert!(
            scheduled.len() <= MAX_EXACT_DEVICES,
            "SolverCost: {} devices exceed the {MAX_EXACT_DEVICES}-slot mask",
            scheduled.len()
        );
        let cands = scheduled.iter().map(|&n| topo.candidate_edges(n)).collect();
        SolverCost {
            topo,
            scheduled,
            cands,
            cache: CostCache::new_solver(topo.params.lambda, opts.clone()),
            memo: HashMap::new(),
            buf: Vec::with_capacity(scheduled.len()),
        }
    }

    /// Solves memoized so far (for instrumentation/tests).
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }
}

impl AssignCost for SolverCost<'_> {
    fn n_slots(&self) -> usize {
        self.scheduled.len()
    }

    fn n_edges(&self) -> usize {
        self.topo.edges.len()
    }

    fn candidates(&self, s: usize) -> &[usize] {
        &self.cands[s]
    }

    fn group_cost(&mut self, m: usize, mask: u64) -> f64 {
        if let Some(&c) = self.memo.get(&(m, mask)) {
            return c;
        }
        // Members listed in scheduled (ascending-slot) order: the solver
        // sees the same device sequence no matter which branch asks.
        self.buf.clear();
        let mut bits = mask;
        while bits != 0 {
            let s = bits.trailing_zeros() as usize;
            self.buf.push(self.scheduled[s]);
            bits &= bits - 1;
        }
        let c = self.cache.eval_group_objective(self.topo, m, &self.buf);
        self.memo.insert((m, mask), c);
        c
    }
}

/// One pop from the best-first frontier, recorded when tracing is on.
/// The stdlib-python mirror re-derives this exact sequence.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub node_id: u64,
    pub depth: usize,
    pub bound: f64,
}

/// A frontier node: `choices[0..depth]` are committed, `marg` prices the
/// remaining slots (rows `depth..n_slots`, flattened row-major over M
/// edges, non-candidate entries = +∞).
struct Node {
    id: u64,
    bound: f64,
    depth: usize,
    choices: Vec<u8>,
    masks: Vec<u64>,
    partial: f64,
    marg: Vec<f64>,
}

/// Heap ordering: smallest bound first, then smallest id. BinaryHeap is a
/// max-heap, so the comparison is reversed.
struct HeapEntry {
    bound: f64,
    id: u64,
    node: Node,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.bound
            .total_cmp(&other.bound)
            .then(self.id.cmp(&other.id))
            .reverse()
    }
}

fn row_min(marg: &[f64], row: usize, m_count: usize) -> f64 {
    let r = &marg[row * m_count..(row + 1) * m_count];
    r.iter().fold(f64::INFINITY, |a, &b| a.min(b))
}

/// Greedy constructive pass: assign slots in order to their
/// cheapest-marginal candidate. Seeds the incumbent so budget-exhausted
/// runs still return a valid assignment, and warms the memo with the
/// masks the search will price first.
fn greedy_seed(eval: &mut dyn AssignCost) -> (Vec<u8>, f64) {
    let n = eval.n_slots();
    let mut masks = vec![0u64; eval.n_edges()];
    let mut choices = Vec::with_capacity(n);
    let mut total = 0.0;
    for s in 0..n {
        let mut best_m = usize::MAX;
        let mut best_delta = f64::INFINITY;
        for &m in &eval.candidates(s).to_vec() {
            let delta = eval.group_cost(m, masks[m] | (1 << s)) - eval.group_cost(m, masks[m]);
            if delta.total_cmp(&best_delta) == Ordering::Less {
                best_delta = delta;
                best_m = m;
            }
        }
        masks[best_m] |= 1 << s;
        choices.push(best_m as u8);
    }
    // Re-fold the exact group sums: the delta accumulation can differ
    // from Σ_m cost(m, mask_m) in the last bits, and leaves re-fold too.
    for m in 0..eval.n_edges() {
        total += eval.group_cost(m, masks[m]);
    }
    (choices, total)
}

/// Best-first branch-and-bound over the cost table. See the module docs
/// for the determinism and degradation contracts.
pub fn branch_and_bound(eval: &mut dyn AssignCost, opts: &ExactOpts) -> ExactResult {
    branch_and_bound_traced(eval, opts, None)
}

/// [`branch_and_bound`] with an optional pop trace (unit tests + the
/// python mirror pin the sequence).
pub fn branch_and_bound_traced(
    eval: &mut dyn AssignCost,
    opts: &ExactOpts,
    mut trace: Option<&mut Vec<TraceEvent>>,
) -> ExactResult {
    let n = eval.n_slots();
    let m_count = eval.n_edges();
    assert!(n <= MAX_EXACT_DEVICES, "branch_and_bound: {n} slots exceed the mask width");
    if n == 0 {
        return ExactResult {
            choices: vec![],
            objective: 0.0,
            lower_bound: 0.0,
            proven: true,
            nodes_expanded: 0,
        };
    }

    let (mut best_choices, mut best_obj) = greedy_seed(eval);

    // Root: nothing committed; marginal row s = cost of slot s alone on
    // each candidate edge.
    let mut marg = vec![f64::INFINITY; n * m_count];
    for s in 0..n {
        for &m in &eval.candidates(s).to_vec() {
            marg[s * m_count + m] = eval.group_cost(m, 1 << s) - eval.group_cost(m, 0);
        }
    }
    let root_bound: f64 = (0..n).map(|s| row_min(&marg, s, m_count)).sum();
    let mut heap = BinaryHeap::new();
    let mut next_id: u64 = 0;
    heap.push(HeapEntry {
        bound: root_bound,
        id: next_id,
        node: Node {
            id: next_id,
            bound: root_bound,
            depth: 0,
            choices: vec![],
            masks: vec![0u64; m_count],
            partial: 0.0,
            marg,
        },
    });
    next_id += 1;

    let started = Instant::now();
    let mut expanded = 0usize;
    let mut proven = true;
    while let Some(entry) = heap.pop() {
        let node = entry.node;
        // The frontier is bound-ordered: once the cheapest open bound
        // cannot beat the incumbent, the incumbent is proven optimal.
        if node.bound >= best_obj - BOUND_SLACK * best_obj.abs() {
            break;
        }
        if expanded >= opts.node_budget
            || opts
                .time_budget_ms
                .is_some_and(|ms| started.elapsed().as_millis() as u64 >= ms)
        {
            // Budget exhausted with provably-open work left: degrade to
            // incumbent + the smallest open bound.
            proven = false;
            let open_min = node.bound;
            let lower = open_min.min(best_obj);
            let r = ExactResult {
                choices: best_choices.iter().map(|&c| c as usize).collect(),
                objective: best_obj,
                lower_bound: lower,
                proven,
                nodes_expanded: expanded,
            };
            debug_assert!(r.lower_bound <= r.objective);
            return r;
        }
        expanded += 1;
        if let Some(t) = trace.as_deref_mut() {
            t.push(TraceEvent { node_id: node.id, depth: node.depth, bound: node.bound });
        }

        let s = node.depth; // slot to branch on (marg row 0)
        for &e in &eval.candidates(s).to_vec() {
            let delta = node.marg[e];
            debug_assert!(delta.is_finite());
            let child_partial = node.partial + delta;
            let child_depth = node.depth + 1;
            if child_depth == n {
                // Leaf: exact objective is the re-folded sum of committed
                // group costs (not the marginal accumulation) so ties and
                // float drift cannot depend on the branch path.
                let mut obj = 0.0;
                for m in 0..m_count {
                    let mask = node.masks[m] | if m == e { 1 << s } else { 0 };
                    obj += eval.group_cost(m, mask);
                }
                if obj.total_cmp(&best_obj) == Ordering::Less {
                    best_obj = obj;
                    best_choices = node.choices.clone();
                    best_choices.push(e as u8);
                }
                continue;
            }
            // Child marginal matrix: rows shift up one slot; only the
            // dirty edge's column is re-priced (every other edge's mask —
            // and therefore marginal — is unchanged).
            let rows = n - child_depth;
            let mut cmarg = vec![f64::INFINITY; rows * m_count];
            for r in 0..rows {
                let parent_row = r + 1; // parent row 0 was slot s
                cmarg[r * m_count..(r + 1) * m_count].copy_from_slice(
                    &node.marg[parent_row * m_count..(parent_row + 1) * m_count],
                );
            }
            let child_mask_e = node.masks[e] | (1 << s);
            let base_e = eval.group_cost(e, child_mask_e);
            for r in 0..rows {
                let slot = child_depth + r;
                cmarg[r * m_count + e] = if eval.candidates(slot).contains(&e) {
                    eval.group_cost(e, child_mask_e | (1 << slot)) - base_e
                } else {
                    f64::INFINITY
                };
            }
            let tail: f64 = (0..rows).map(|r| row_min(&cmarg, r, m_count)).sum();
            let child_bound = child_partial + tail;
            if child_bound >= best_obj - BOUND_SLACK * best_obj.abs() {
                continue; // prune
            }
            let mut cchoices = node.choices.clone();
            cchoices.push(e as u8);
            let mut cmasks = node.masks.clone();
            cmasks[e] = child_mask_e;
            heap.push(HeapEntry {
                bound: child_bound,
                id: next_id,
                node: Node {
                    id: next_id,
                    bound: child_bound,
                    depth: child_depth,
                    choices: cchoices,
                    masks: cmasks,
                    partial: child_partial,
                    marg: cmarg,
                },
            });
            next_id += 1;
        }
    }

    let r = ExactResult {
        choices: best_choices.iter().map(|&c| c as usize).collect(),
        objective: best_obj,
        lower_bound: best_obj,
        proven,
        nodes_expanded: expanded,
    };
    debug_assert!(r.lower_bound <= r.objective);
    r
}

/// High-level entry: solve the scheduled set on `topo` exactly. Returns
/// `None` when the cell is too large for the 64-slot mask — callers fall
/// back to a heuristic (`oracle?fallback=`) or skip the gap row.
pub fn solve_assignment(
    topo: &Topology,
    scheduled: &[usize],
    opts: &SolverOpts,
    exact: &ExactOpts,
) -> Option<ExactSolve> {
    if scheduled.len() > MAX_EXACT_DEVICES {
        return None;
    }
    let mut eval = SolverCost::new(topo, scheduled, opts);
    let res = branch_and_bound(&mut eval, exact);
    // Debug-build cross-check: the exhaustive enumerator (bruteforce.rs)
    // must agree bit-for-bit whenever the tree is small enough to close.
    #[cfg(debug_assertions)]
    if res.proven {
        if let Some((_, obj)) =
            crate::allocation::bruteforce::enumerate_assignments(&mut eval, 200_000)
        {
            debug_assert!(
                res.objective.to_bits() == obj.to_bits(),
                "B&B {:.17e} != enumeration {:.17e}",
                res.objective,
                obj
            );
        }
    }
    let mut assignment = Assignment::empty(topo.edges.len());
    for (slot, &m) in res.choices.iter().enumerate() {
        assignment.groups[m].push(scheduled[slot]);
    }
    Some(ExactSolve {
        assignment,
        objective: res.objective,
        lower_bound: res.lower_bound,
        proven: res.proven,
        nodes_expanded: res.nodes_expanded,
    })
}

/// [`solve_assignment`] result with the choices materialized as an
/// [`Assignment`] (groups in scheduled order).
#[derive(Clone, Debug)]
pub struct ExactSolve {
    pub assignment: Assignment,
    pub objective: f64,
    pub lower_bound: f64,
    pub proven: bool,
    pub nodes_expanded: usize,
}

/// Surrogate F of an arbitrary assignment with every group canonicalized
/// into scheduled order before evaluation — the *same* floats the oracle's
/// memoized leaves produce for the same partition, so gaps computed as
/// `F_arm − F_oracle` can never go negative from member-order drift.
pub fn surrogate_of(
    topo: &Topology,
    scheduled: &[usize],
    assignment: &Assignment,
    opts: &SolverOpts,
) -> f64 {
    let mut slot_of = HashMap::with_capacity(scheduled.len());
    for (i, &n) in scheduled.iter().enumerate() {
        slot_of.insert(n, i);
    }
    let mut cache = CostCache::new_solver(topo.params.lambda, opts.clone());
    let mut total = 0.0;
    let mut group = Vec::new();
    for (m, g) in assignment.groups.iter().enumerate() {
        group.clear();
        group.extend(g.iter().copied());
        group.sort_by_key(|n| slot_of.get(n).copied().unwrap_or(usize::MAX));
        total += cache.eval_group_objective(topo, m, &group);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Closed-form supermodular table with exactly-representable values
    /// (multiples of 0.25): cost(m, mask) = w[m]·k + q[m]·k(k−1)/2 +
    /// Σ_{s∈mask} a[s][m], k = popcount. Marginal of adding slot s to a
    /// size-k group is w[m] + q[m]·k + a[s][m], non-decreasing in k for
    /// q ≥ 0 — the supermodularity the bound's admissibility rests on.
    /// The python mirror (python/tests/test_exact_oracle_mirror.py)
    /// re-implements this table and pins the same trace constants.
    pub(super) struct TableCost {
        pub w: Vec<f64>,
        pub q: Vec<f64>,
        pub a: Vec<Vec<f64>>, // a[slot][edge]
        pub cands: Vec<Vec<usize>>,
    }

    impl AssignCost for TableCost {
        fn n_slots(&self) -> usize {
            self.a.len()
        }
        fn n_edges(&self) -> usize {
            self.w.len()
        }
        fn candidates(&self, s: usize) -> &[usize] {
            &self.cands[s]
        }
        fn group_cost(&mut self, m: usize, mask: u64) -> f64 {
            let k = mask.count_ones() as f64;
            let mut c = self.w[m] * k + self.q[m] * k * (k - 1.0) / 2.0;
            let mut bits = mask;
            while bits != 0 {
                let s = bits.trailing_zeros() as usize;
                c += self.a[s][m];
                bits &= bits - 1;
            }
            c
        }
    }

    /// The 3-slot / 2-edge fixture shared bit-for-bit with the python
    /// mirror. Built so the greedy seed is *suboptimal* (it myopically
    /// piles everything on congested edge 0, F = 6.0) while the unique
    /// optimum routes slot 0 to edge 1 (F = 4.25) — forcing the search
    /// to actually dig. Keep in sync with test_exact_oracle_mirror.py.
    pub(super) fn mirror_fixture() -> TableCost {
        TableCost {
            w: vec![1.0, 1.0],
            q: vec![1.0, 0.0], // edge 0 congests hard; edge 1 is flat
            a: vec![
                vec![0.0, 0.25], // slot 0 mildly prefers edge 0
                vec![0.0, 2.0],  // slots 1,2 strongly prefer edge 0
                vec![0.0, 2.0],
            ],
            cands: vec![vec![0, 1], vec![0, 1], vec![0, 1]],
        }
    }

    #[test]
    fn table_optimum_matches_enumeration() {
        let mut t = mirror_fixture();
        let res = branch_and_bound(&mut t, &ExactOpts::default());
        assert!(res.proven);
        // Exhaustive check: 2^3 assignments.
        let mut best = f64::INFINITY;
        let mut t2 = mirror_fixture();
        for c0 in 0..2u64 {
            for c1 in 0..2u64 {
                for c2 in 0..2u64 {
                    let mut masks = [0u64; 2];
                    masks[c0 as usize] |= 1;
                    masks[c1 as usize] |= 2;
                    masks[c2 as usize] |= 4;
                    let f = t2.group_cost(0, masks[0]) + t2.group_cost(1, masks[1]);
                    if f < best {
                        best = f;
                    }
                }
            }
        }
        assert_eq!(res.objective.to_bits(), best.to_bits());
        assert_eq!(res.lower_bound.to_bits(), best.to_bits());
    }

    /// Pinned optimum + trace for the mirror fixture. These constants are
    /// duplicated in python/tests/test_exact_oracle_mirror.py — a change
    /// here that isn't mirrored there is a determinism-contract break.
    #[test]
    fn mirror_trace_is_pinned() {
        let mut t = mirror_fixture();
        let mut trace = Vec::new();
        let res = branch_and_bound_traced(&mut t, &ExactOpts::default(), Some(&mut trace));
        // Optimum: slot0→e1 (1.25), slots 1,2→e0 (3.0) = 4.25, unique.
        assert_eq!(res.objective, 4.25);
        assert_eq!(res.choices, vec![1, 0, 0]);
        assert!(res.proven);
        assert_eq!(res.lower_bound, 4.25);
        let got: Vec<(u64, usize, f64)> =
            trace.iter().map(|e| (e.node_id, e.depth, e.bound)).collect();
        // Root bound: min(1,1.25)+min(1,3)+min(1,3) = 3.0. Children of
        // the root: slot0→e0 bound 5.0 (id 1), slot0→e1 bound 3.25
        // (id 2); best-first pops id 2, whose slot1→e0 child (id 3,
        // bound 4.25) leafs into the optimum; the surviving id 1 then
        // fails 5.0 < incumbent and the search closes.
        assert_eq!(got, vec![(0, 0, 3.0), (2, 1, 3.25), (3, 2, 4.25)]);
        assert_eq!(res.nodes_expanded, 3);
    }

    #[test]
    fn greedy_seed_is_deterministic_and_valid() {
        let mut t = mirror_fixture();
        let (choices, obj) = greedy_seed(&mut t);
        // Myopic: slot0→e0 (1.0 < 1.25), slot1→e0 (Δ2.0 < 3.0), slot2
        // ties (Δ3.0 both) and the strict-< first-min keeps e0.
        assert_eq!(choices, vec![0, 0, 0]);
        assert_eq!(obj, 6.0);
    }

    /// Equal-bound frontier nodes pop in creation (id) order. Fully
    /// symmetric table: the root's two children tie at bound 3.0. The
    /// trace constants are co-pinned by the python mirror's
    /// `test_tie_breaks_prefer_lower_node_id`.
    #[test]
    fn equal_bound_ties_pop_in_id_order() {
        let mut t = TableCost {
            w: vec![1.0, 1.0],
            q: vec![1.0, 1.0],
            a: vec![vec![0.0, 0.0], vec![0.0, 0.0], vec![0.0, 0.0]],
            cands: vec![vec![0, 1], vec![0, 1], vec![0, 1]],
        };
        let mut trace = Vec::new();
        let res = branch_and_bound_traced(&mut t, &ExactOpts::default(), Some(&mut trace));
        assert_eq!(res.objective, 4.0); // any 2+1 split: 3 + 1
        assert_eq!(res.choices, vec![0, 1, 0]); // greedy's split survives
        assert!(res.proven);
        let got: Vec<(u64, usize, f64)> =
            trace.iter().map(|e| (e.node_id, e.depth, e.bound)).collect();
        assert_eq!(got, vec![(0, 0, 3.0), (1, 1, 3.0), (2, 1, 3.0)]);
        assert_eq!(res.nodes_expanded, 3);
    }

    #[test]
    fn node_budget_degrades_to_incumbent() {
        let mut t = mirror_fixture();
        let res = branch_and_bound(&mut t, &ExactOpts { node_budget: 1, time_budget_ms: None });
        assert!(!res.proven);
        assert_eq!(res.choices, vec![0, 0, 0]); // greedy incumbent, still valid
        assert_eq!(res.objective, 6.0);
        assert_eq!(res.lower_bound, 3.25); // smallest open bound at exhaustion
    }

    #[test]
    fn zero_slots_is_trivially_proven() {
        struct Empty;
        impl AssignCost for Empty {
            fn n_slots(&self) -> usize {
                0
            }
            fn n_edges(&self) -> usize {
                2
            }
            fn candidates(&self, _: usize) -> &[usize] {
                &[]
            }
            fn group_cost(&mut self, _: usize, _: u64) -> f64 {
                0.0
            }
        }
        let res = branch_and_bound(&mut Empty, &ExactOpts::default());
        assert!(res.proven);
        assert_eq!(res.objective, 0.0);
    }
}
