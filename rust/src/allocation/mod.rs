//! Per-edge convex resource allocation (problem 27): bandwidth split `b_n`
//! and CPU frequency `f_n` for the devices assigned to one edge server.
//!
//! `solver` is the production epigraph solver (replaces the paper's CVXPY,
//! DESIGN.md §5); `bruteforce` is the grid oracle used by the test suite;
//! `cache` is the incremental objective-(17) evaluator that lets search
//! loops re-solve only the edges a candidate move touches.

pub mod bruteforce;
pub mod cache;
pub mod solver;

pub use cache::CostCache;
pub use solver::{solve_edge, AllocSolution, SolverOpts};
