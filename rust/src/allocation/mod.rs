//! Per-edge convex resource allocation (problem 27): bandwidth split `b_n`
//! and CPU frequency `f_n` for the devices assigned to one edge server.
//!
//! `solver` is the production epigraph solver (replaces the paper's CVXPY,
//! DESIGN.md §5); `bruteforce` holds the exhaustive oracles — a bandwidth
//! grid check for the solver and an assignment-space enumerator for the
//! exact subsystem; `cache` is the incremental objective-(17) evaluator
//! that lets search loops re-solve only the edges a candidate move
//! touches; `exact` is the branch-and-bound assignment oracle built on
//! both (DESIGN.md §12).

pub mod bruteforce;
pub mod cache;
pub mod exact;
pub mod solver;

pub use cache::CostCache;
pub use exact::{branch_and_bound, AssignCost, ExactOpts, ExactResult, ExactSolve, SolverCost};
pub use solver::{solve_edge, AllocSolution, SolverOpts};
