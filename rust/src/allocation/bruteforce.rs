//! Brute-force oracles: a bandwidth-grid check for the per-edge solver
//! and an exhaustive assignment-space enumerator for the exact subsystem.
//!
//! * [`solve_bruteforce`] — for ≤3 devices: grid over the bandwidth
//!   simplex; for each bandwidth vector the remaining problem is 1-D
//!   convex in the round time τ (frequencies are closed-form given τ),
//!   solved by fine golden-section. The solver in `solver.rs` must match
//!   this within a small relative gap (tests).
//! * [`enumerate_assignments`] / [`enumerate_topology`] — the M^N sweep
//!   over device→edge choices that the branch-and-bound in
//!   `allocation/exact` must agree with bit-for-bit. Runs through the
//!   same [`AssignCost`] table (memoized edge-subset solves), guarded by
//!   an N·M^N work budget so a mis-sized call fails loudly instead of
//!   spinning.

use crate::allocation::exact::{AssignCost, SolverCost, MAX_EXACT_DEVICES};
use crate::allocation::SolverOpts;
use crate::assignment::Assignment;
use crate::system::cost::{cloud_cost, edge_cost, DeviceAlloc};
use crate::system::Topology;

/// Exhaustively enumerate every device→edge assignment over the cost
/// table's candidate lists and return the argmin `(choices, objective)`
/// (strict `<`: the lexicographically-first optimum wins ties, matching
/// the deterministic candidate order). Returns `None` — rather than
/// hanging — when the N·M^N leaf-evaluation work estimate exceeds
/// `budget`. Objectives are re-folded sums of per-edge group costs, so a
/// proven branch-and-bound run over the same table yields bit-identical
/// floats.
pub fn enumerate_assignments(
    eval: &mut dyn AssignCost,
    budget: u64,
) -> Option<(Vec<usize>, f64)> {
    let n = eval.n_slots();
    let m_count = eval.n_edges();
    if n > MAX_EXACT_DEVICES {
        return None;
    }
    // Work estimate: N · Π |candidates(s)| (saturating — huge is huge).
    let mut leaves: u64 = 1;
    for s in 0..n {
        leaves = leaves.saturating_mul(eval.candidates(s).len().max(1) as u64);
    }
    if (n as u64).saturating_mul(leaves) > budget {
        return None;
    }
    if n == 0 {
        return Some((vec![], 0.0));
    }

    let mut best_obj = f64::INFINITY;
    let mut best_choices: Vec<usize> = vec![];
    let mut choices: Vec<usize> = Vec::with_capacity(n);
    let mut masks = vec![0u64; m_count];
    // Depth-first product of candidate lists, lexicographic over the
    // per-slot candidate order.
    fn rec(
        eval: &mut dyn AssignCost,
        s: usize,
        n: usize,
        masks: &mut Vec<u64>,
        choices: &mut Vec<usize>,
        best_obj: &mut f64,
        best_choices: &mut Vec<usize>,
    ) {
        if s == n {
            let mut obj = 0.0;
            for m in 0..masks.len() {
                obj += eval.group_cost(m, masks[m]);
            }
            if obj.total_cmp(best_obj) == std::cmp::Ordering::Less {
                *best_obj = obj;
                *best_choices = choices.clone();
            }
            return;
        }
        for &e in &eval.candidates(s).to_vec() {
            masks[e] |= 1 << s;
            choices.push(e);
            rec(eval, s + 1, n, masks, choices, best_obj, best_choices);
            choices.pop();
            masks[e] &= !(1 << s);
        }
    }
    rec(eval, 0, n, &mut masks, &mut choices, &mut best_obj, &mut best_choices);
    Some((best_choices, best_obj))
}

/// [`enumerate_assignments`] over a real topology: builds the same
/// memoized [`SolverCost`] table the exact solver uses and materializes
/// the argmin as an [`Assignment`] (groups in scheduled order).
pub fn enumerate_topology(
    topo: &Topology,
    scheduled: &[usize],
    opts: &SolverOpts,
    budget: u64,
) -> Option<(Assignment, f64)> {
    if scheduled.len() > MAX_EXACT_DEVICES {
        return None;
    }
    let mut eval = SolverCost::new(topo, scheduled, opts);
    let (choices, obj) = enumerate_assignments(&mut eval, budget)?;
    let mut a = Assignment::empty(topo.edges.len());
    for (slot, &m) in choices.iter().enumerate() {
        a.groups[m].push(scheduled[slot]);
    }
    Some((a, obj))
}

/// Evaluate the exact objective for a fixed bandwidth split by optimizing
/// τ (and hence f) by golden-section.
fn best_over_tau(
    topo: &Topology,
    m: usize,
    devices: &[usize],
    bw: &[f64],
    lambda: f64,
) -> (f64, Vec<DeviceAlloc>) {
    let p = &topo.params;
    let z = p.model_bits;

    let t_com: Vec<f64> = devices
        .iter()
        .zip(bw)
        .map(|(&n, &b)| {
            z / topo.channel.rate(b, topo.gain(n, m), topo.fleet.tx_power_w(n))
        })
        .collect();
    let c: Vec<f64> = devices
        .iter()
        .map(|&n| {
            let d = topo.device(n);
            p.local_iters as f64 * d.cycles_per_sample * d.num_samples as f64
        })
        .collect();

    let eval = |tau: f64| -> Option<(f64, Vec<DeviceAlloc>)> {
        let mut allocs = Vec::with_capacity(devices.len());
        for i in 0..devices.len() {
            let slack = tau - t_com[i];
            if slack <= 0.0 {
                return None;
            }
            let f = c[i] / slack;
            if f > topo.fleet.max_freq_hz() {
                return None;
            }
            allocs.push(DeviceAlloc { bandwidth_hz: bw[i], freq_hz: f });
        }
        let group: Vec<(usize, DeviceAlloc)> =
            devices.iter().cloned().zip(allocs.iter().cloned()).collect();
        let ec = edge_cost(topo, m, &group);
        Some((ec.e + lambda * ec.t, allocs))
    };

    // bracket: τ_lo = max infeasible floor, τ_hi grows until objective rises
    let tau_floor = (0..devices.len())
        .map(|i| t_com[i] + c[i] / topo.fleet.max_freq_hz())
        .fold(0.0f64, f64::max)
        * 1.000001;
    let mut tau_hi = tau_floor * 2.0;
    let mut best_hi = eval(tau_hi);
    loop {
        let cand = tau_hi * 1.5;
        let e = eval(cand);
        match (&best_hi, &e) {
            (Some((a, _)), Some((b, _))) if b < a => {
                tau_hi = cand;
                best_hi = e;
            }
            (None, _) => {
                tau_hi = cand;
                best_hi = e;
            }
            _ => break,
        }
        if tau_hi > tau_floor * 1e7 {
            break;
        }
    }
    tau_hi *= 1.5;

    let gr = (5f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (tau_floor, tau_hi);
    for _ in 0..200 {
        let x1 = b - gr * (b - a);
        let x2 = a + gr * (b - a);
        let f1 = eval(x1).map(|(v, _)| v).unwrap_or(f64::INFINITY);
        let f2 = eval(x2).map(|(v, _)| v).unwrap_or(f64::INFINITY);
        if f1 <= f2 {
            b = x2;
        } else {
            a = x1;
        }
        if (b - a) < 1e-7 * b {
            break;
        }
    }
    let tau = 0.5 * (a + b);
    eval(tau).map(|(v, al)| (v, al)).unwrap_or((f64::INFINITY, vec![]))
}

/// Brute-force solve for 1–3 devices with a bandwidth grid of `grid` points
/// per dimension. Returns (objective, allocations).
pub fn solve_bruteforce(
    topo: &Topology,
    m: usize,
    devices: &[usize],
    lambda: f64,
    grid: usize,
) -> (f64, Vec<DeviceAlloc>) {
    let b_total = topo.edges[m].bandwidth_hz;
    if devices.is_empty() {
        return (0.0, vec![]);
    }
    let (_, e_cloud) = cloud_cost(topo, m);
    let _ = e_cloud;
    match devices.len() {
        1 => best_over_tau(topo, m, devices, &[b_total], lambda),
        2 => {
            let mut best = (f64::INFINITY, vec![]);
            for i in 1..grid {
                let w = i as f64 / grid as f64;
                let bw = [b_total * w, b_total * (1.0 - w)];
                let r = best_over_tau(topo, m, devices, &bw, lambda);
                if r.0 < best.0 {
                    best = r;
                }
            }
            best
        }
        3 => {
            let mut best = (f64::INFINITY, vec![]);
            for i in 1..grid {
                for j in 1..grid - i {
                    let w1 = i as f64 / grid as f64;
                    let w2 = j as f64 / grid as f64;
                    let w3 = 1.0 - w1 - w2;
                    if w3 <= 0.0 {
                        continue;
                    }
                    let bw = [b_total * w1, b_total * w2, b_total * w3];
                    let r = best_over_tau(topo, m, devices, &bw, lambda);
                    if r.0 < best.0 {
                        best = r;
                    }
                }
            }
            best
        }
        _ => panic!("brute force supports ≤3 devices"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::solver::{solve_edge, SolverOpts};
    use crate::system::{SystemParams, Topology};
    use crate::util::Rng;

    fn check_gap(seed: u64, devices: &[usize], lambda: f64, tol: f64) {
        let topo = Topology::generate(&SystemParams::default(), &mut Rng::new(seed));
        let (bf_obj, _) = solve_bruteforce(&topo, 0, devices, lambda, 60);
        let s = solve_edge(&topo, 0, devices, lambda, &SolverOpts::default());
        let gap = (s.objective - bf_obj) / bf_obj.abs();
        // the solver must be no worse than the grid oracle + tolerance
        // (it may be better: the grid is finite)
        assert!(
            gap < tol,
            "seed {seed} devices {devices:?} λ={lambda}: solver {} vs brute {} (gap {gap:.4})",
            s.objective,
            bf_obj
        );
    }

    #[test]
    fn matches_oracle_single_device() {
        check_gap(1, &[0], 1.0, 0.01);
        check_gap(2, &[7], 1.0, 0.01);
    }

    #[test]
    fn matches_oracle_two_devices() {
        check_gap(3, &[1, 2], 1.0, 0.015);
        check_gap(4, &[10, 40], 1.0, 0.015);
    }

    #[test]
    fn matches_oracle_three_devices() {
        check_gap(5, &[3, 14, 25], 1.0, 0.02);
    }

    #[test]
    fn matches_oracle_extreme_lambda() {
        check_gap(6, &[2, 9], 0.01, 0.02);
        check_gap(7, &[2, 9], 100.0, 0.02);
    }
}
