//! Device schedulers: FedAvg (random), VKC (Algorithm 3) and IKC
//! (Algorithm 4).
//!
//! VKC/IKC operate on the K clusters produced by Algorithm 2
//! (`clustering.rs`); per global iteration they draw `h = H/K` devices per
//! cluster so the union dataset `D_H` approximates class balance (§IV).
//! IKC additionally keeps per-cluster history sets `G_k` that prioritize
//! not-recently-scheduled devices, fixing VKC's repetitive-scheduling flaw.

use crate::util::Rng;

/// A device scheduler: selects the subset `H_i ⊆ N` per global iteration.
pub trait Scheduler {
    fn schedule(&mut self) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// FedAvg: uniformly random H devices [3].
// ---------------------------------------------------------------------------

pub struct FedAvg {
    n_devices: usize,
    h: usize,
    rng: Rng,
}

impl FedAvg {
    pub fn new(n_devices: usize, h: usize, seed: u64) -> Self {
        assert!(h <= n_devices);
        FedAvg { n_devices, h, rng: Rng::new(seed) }
    }
}

impl Scheduler for FedAvg {
    fn schedule(&mut self) -> Vec<usize> {
        let mut v = self.rng.sample_indices(self.n_devices, self.h);
        v.sort_unstable();
        v
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

// ---------------------------------------------------------------------------
// Shared VKC/IKC helper: top-up from unscheduled devices (Alg. 3 L12-14).
// ---------------------------------------------------------------------------

/// Paper-scale fleets (N ≤ this) take the original materialize-the-pool
/// path, which keeps the RNG call sequence — and thus every golden CSV —
/// byte-identical. Larger fleets switch to rejection sampling.
const TOP_UP_DENSE_LIMIT: usize = 4096;

fn top_up(selected: &mut Vec<usize>, n_devices: usize, target: usize, rng: &mut Rng) {
    if selected.len() >= target {
        return;
    }
    let chosen: std::collections::HashSet<usize> = selected.iter().cloned().collect();
    if n_devices <= TOP_UP_DENSE_LIMIT {
        let pool: Vec<usize> = (0..n_devices).filter(|n| !chosen.contains(n)).collect();
        let extra = (target - selected.len()).min(pool.len());
        selected.extend(rng.sample(&pool, extra));
        return;
    }
    // Million-device fleets: the complement pool is huge and the deficit
    // tiny, so draw by rejection instead of materializing O(N) indices.
    // Deterministic for a fixed RNG state; duplicates are rejected against
    // both the prior selection and this top-up's own draws.
    let extra = (target - selected.len()).min(n_devices - chosen.len().min(n_devices));
    let mut picked: std::collections::HashSet<usize> = std::collections::HashSet::new();
    let mut attempts = 16 * extra + 64;
    while picked.len() < extra && attempts > 0 {
        attempts -= 1;
        let n = rng.below(n_devices);
        if !chosen.contains(&n) && picked.insert(n) {
            selected.push(n);
        }
    }
    if picked.len() < extra {
        // Pathological acceptance rate (selection covers almost all of N):
        // finish with a wrap-around linear scan from a random offset, which
        // is deterministic and always terminates.
        let start = rng.below(n_devices);
        let mut n = start;
        while picked.len() < extra {
            if !chosen.contains(&n) && picked.insert(n) {
                selected.push(n);
            }
            n = (n + 1) % n_devices;
            if n == start {
                break; // complement exhausted
            }
        }
    }
}

// ---------------------------------------------------------------------------
// VKC — Algorithm 3.
// ---------------------------------------------------------------------------

pub struct Vkc {
    clusters: Vec<Vec<usize>>,
    n_devices: usize,
    /// devices per cluster per iteration, `h`.
    h_per_cluster: usize,
    rng: Rng,
}

impl Vkc {
    pub fn new(clusters: Vec<Vec<usize>>, n_devices: usize, h_total: usize, seed: u64) -> Self {
        let k = clusters.len();
        assert!(k > 0 && h_total % k == 0, "H={h_total} must be a multiple of K={k}");
        Vkc { clusters, n_devices, h_per_cluster: h_total / k, rng: Rng::new(seed) }
    }
}

impl Scheduler for Vkc {
    fn schedule(&mut self) -> Vec<usize> {
        let h = self.h_per_cluster;
        let target = h * self.clusters.len();
        let mut selected = Vec::with_capacity(target);
        for ck in &self.clusters {
            if ck.len() >= h {
                selected.extend(self.rng.sample(ck, h)); // Alg.3 L7
            } else {
                selected.extend(ck.iter().cloned()); // Alg.3 L9
            }
        }
        top_up(&mut selected, self.n_devices, target, &mut self.rng);
        selected.sort_unstable();
        selected
    }

    fn name(&self) -> &'static str {
        "vkc"
    }
}

// ---------------------------------------------------------------------------
// IKC — Algorithm 4.
// ---------------------------------------------------------------------------

pub struct Ikc {
    /// Current unscheduled pools `C_k` (devices move out when scheduled).
    pools: Vec<Vec<usize>>,
    /// History sets `G_k` of recently scheduled devices.
    history: Vec<Vec<usize>>,
    n_devices: usize,
    h_per_cluster: usize,
    rng: Rng,
}

impl Ikc {
    pub fn new(clusters: Vec<Vec<usize>>, n_devices: usize, h_total: usize, seed: u64) -> Self {
        let k = clusters.len();
        assert!(k > 0 && h_total % k == 0, "H={h_total} must be a multiple of K={k}");
        Ikc {
            history: vec![Vec::new(); k],
            pools: clusters,
            n_devices,
            h_per_cluster: h_total / k,
            rng: Rng::new(seed),
        }
    }

    /// Number of distinct devices tracked for cluster k (C_k ∪ G_k).
    #[cfg(test)]
    fn cluster_size(&self, k: usize) -> usize {
        self.pools[k].len() + self.history[k].len()
    }
}

impl Scheduler for Ikc {
    fn schedule(&mut self) -> Vec<usize> {
        let h = self.h_per_cluster;
        let k_count = self.pools.len();
        let target = h * k_count;
        let mut selected = Vec::with_capacity(target);

        for k in 0..k_count {
            let ck_len = self.pools[k].len();
            let gk_len = self.history[k].len();
            let mut hk: Vec<usize> = Vec::with_capacity(h);
            if ck_len + gk_len >= h {
                if ck_len >= h {
                    // Alg.4 L9: draw h fresh devices from C_k; record in G_k
                    let mut pool = std::mem::take(&mut self.pools[k]);
                    for _ in 0..h {
                        let i = self.rng.below(pool.len());
                        hk.push(pool.swap_remove(i));
                    }
                    self.pools[k] = pool;
                    self.history[k].extend(hk.iter().cloned());
                } else {
                    // Alg.4 L11-14: exhaust C_k, borrow the rest from G_k,
                    // then recycle G_k into C_k and restart history with H_k
                    hk.extend(self.pools[k].drain(..));
                    let mut g = std::mem::take(&mut self.history[k]);
                    for _ in 0..(h - hk.len()) {
                        let i = self.rng.below(g.len());
                        hk.push(g.swap_remove(i));
                    }
                    self.pools[k] = g; // remaining history becomes the pool
                    self.history[k] = hk.clone();
                }
            } else {
                // Alg.4 L17: cluster smaller than h — take everything
                hk.extend(self.pools[k].iter().cloned());
                hk.extend(self.history[k].iter().cloned());
            }
            selected.extend(hk);
        }

        top_up(&mut selected, self.n_devices, target, &mut self.rng);
        selected.sort_unstable();
        selected.dedup();
        selected
    }

    fn name(&self) -> &'static str {
        "ikc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clusters_10x10() -> Vec<Vec<usize>> {
        (0..10).map(|k| (0..10).map(|i| k * 10 + i).collect()).collect()
    }

    #[test]
    fn fedavg_selects_h_distinct() {
        let mut s = FedAvg::new(100, 30, 1);
        for _ in 0..5 {
            let sel = s.schedule();
            assert_eq!(sel.len(), 30);
            let mut d = sel.clone();
            d.dedup();
            assert_eq!(d.len(), 30);
        }
    }

    #[test]
    fn vkc_draws_h_per_cluster() {
        let mut s = Vkc::new(clusters_10x10(), 100, 50, 2);
        let sel = s.schedule();
        assert_eq!(sel.len(), 50);
        for k in 0..10 {
            let in_k = sel.iter().filter(|&&n| n / 10 == k).count();
            assert_eq!(in_k, 5, "cluster {k}");
        }
    }

    #[test]
    fn vkc_small_cluster_tops_up() {
        // one cluster has 2 devices < h=5: total still H via top-up
        let mut clusters = clusters_10x10();
        clusters[0] = vec![0, 1];
        let mut s = Vkc::new(clusters, 100, 50, 3);
        let sel = s.schedule();
        assert_eq!(sel.len(), 50);
    }

    #[test]
    fn ikc_avoids_repeats_until_pool_exhausted() {
        // h=5, clusters of 10: two consecutive iterations must be disjoint
        let mut s = Ikc::new(clusters_10x10(), 100, 50, 4);
        let a = s.schedule();
        let b = s.schedule();
        let inter: Vec<usize> =
            a.iter().filter(|n| b.contains(n)).cloned().collect();
        assert!(inter.is_empty(), "repeat before exhaustion: {inter:?}");
        // iteration 3 must reuse (pool exhausted after 2 rounds)
        let c = s.schedule();
        assert_eq!(c.len(), 50);
    }

    #[test]
    fn ikc_covers_all_devices_over_two_rounds() {
        let mut s = Ikc::new(clusters_10x10(), 100, 50, 5);
        let mut seen: Vec<usize> = s.schedule();
        seen.extend(s.schedule());
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 100, "every device scheduled within N/H rounds");
    }

    #[test]
    fn ikc_conserves_devices() {
        let mut s = Ikc::new(clusters_10x10(), 100, 50, 6);
        for _ in 0..7 {
            s.schedule();
            for k in 0..10 {
                assert_eq!(s.cluster_size(k), 10, "cluster {k} leaked devices");
            }
        }
    }

    #[test]
    fn ikc_h_equals_n_schedules_everyone() {
        let mut s = Ikc::new(clusters_10x10(), 100, 100, 7);
        let sel = s.schedule();
        assert_eq!(sel, (0..100).collect::<Vec<_>>());
        let sel2 = s.schedule();
        assert_eq!(sel2.len(), 100);
    }

    #[test]
    #[should_panic]
    fn vkc_rejects_nondivisible_h() {
        Vkc::new(clusters_10x10(), 100, 37, 8);
    }

    #[test]
    fn top_up_small_fleet_matches_legacy_draws() {
        // transcription of the pre-rejection-sampling implementation: the
        // gated path must consume the RNG identically (golden-CSV contract)
        let legacy = |selected: &mut Vec<usize>, n: usize, target: usize, rng: &mut Rng| {
            let chosen: std::collections::HashSet<usize> =
                selected.iter().cloned().collect();
            let pool: Vec<usize> = (0..n).filter(|d| !chosen.contains(d)).collect();
            let extra = (target - selected.len()).min(pool.len());
            selected.extend(rng.sample(&pool, extra));
        };
        for seed in [1u64, 7, 42] {
            let mut a = vec![5, 17, 40];
            let mut b = a.clone();
            top_up(&mut a, 100, 10, &mut Rng::new(seed));
            legacy(&mut b, 100, 10, &mut Rng::new(seed));
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn top_up_large_fleet_is_deterministic_and_distinct() {
        let mut a = vec![0, 1, 2];
        let mut b = a.clone();
        top_up(&mut a, 100_000, 50, &mut Rng::new(9));
        top_up(&mut b, 100_000, 50, &mut Rng::new(9));
        assert_eq!(a, b, "rejection sampling must be deterministic");
        assert_eq!(a.len(), 50);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 50, "duplicates slipped through");
    }

    #[test]
    fn top_up_large_fleet_scan_fallback_when_nearly_full() {
        // complement of 3 devices in a >4096 fleet: rejection sampling is
        // hopeless, the wrap-around scan must still find every free device
        let n = TOP_UP_DENSE_LIMIT + 10;
        let mut sel: Vec<usize> = (0..n - 3).collect();
        top_up(&mut sel, n, n, &mut Rng::new(1));
        assert_eq!(sel.len(), n);
        let mut d = sel.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), n);
    }
}
