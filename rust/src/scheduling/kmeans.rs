//! K-means with k-means++ initialization, used by Algorithm 2 to cluster
//! devices by their trained auxiliary-model weights.

use crate::util::Rng;

/// Result of a K-means run.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub centroids: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub inertia: f64,
    pub iterations: usize,
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

/// k-means++ seeding.
fn init_pp(points: &[Vec<f32>], k: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let n = points.len();
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(points[rng.below(n)].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| sq_dist(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.below(n) // all points identical to some centroid
        } else {
            let mut r = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                if r < d {
                    pick = i;
                    break;
                }
                r -= d;
            }
            pick
        };
        centroids.push(points[next].clone());
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(sq_dist(p, centroids.last().unwrap()));
        }
    }
    centroids
}

/// Run K-means with `n_init` k-means++ restarts, keeping the best inertia.
pub fn kmeans_restarts(
    points: &[Vec<f32>],
    k: usize,
    max_iters: usize,
    n_init: usize,
    rng: &mut Rng,
) -> KMeans {
    let mut best: Option<KMeans> = None;
    for _ in 0..n_init.max(1) {
        let km = kmeans(points, k, max_iters, rng);
        if best.as_ref().map_or(true, |b| km.inertia < b.inertia) {
            best = Some(km);
        }
    }
    best.unwrap()
}

/// Run K-means. `points` must be non-empty, all of equal dimension, and
/// `k <= points.len()`.
pub fn kmeans(points: &[Vec<f32>], k: usize, max_iters: usize, rng: &mut Rng) -> KMeans {
    assert!(!points.is_empty() && k > 0 && k <= points.len());
    let dim = points[0].len();
    assert!(points.iter().all(|p| p.len() == dim));

    let mut centroids = init_pp(points, k, rng);
    let mut labels = vec![0usize; points.len()];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // assign
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    sq_dist(p, &centroids[a]).total_cmp(&sq_dist(p, &centroids[b]))
                })
                .unwrap();
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        // update
        let mut sums = vec![vec![0.0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[labels[i]] += 1;
            for (s, &x) in sums[labels[i]].iter_mut().zip(p.iter()) {
                *s += x as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed an empty cluster at the farthest point
                let far = (0..points.len())
                    .max_by(|&a, &b| {
                        sq_dist(&points[a], &centroids[labels[a]])
                            .total_cmp(&sq_dist(&points[b], &centroids[labels[b]]))
                    })
                    .unwrap();
                centroids[c] = points[far].clone();
            } else {
                for (j, s) in sums[c].iter().enumerate() {
                    centroids[c][j] = (s / counts[c] as f64) as f32;
                }
            }
        }
        if !changed && it > 0 {
            break;
        }
    }

    let inertia = points
        .iter()
        .zip(&labels)
        .map(|(p, &l)| sq_dist(p, &centroids[l]))
        .sum();
    KMeans { centroids, labels, inertia, iterations }
}

/// Group indices by label into `k` clusters.
pub fn clusters_from_labels(labels: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); k];
    for (i, &l) in labels.iter().enumerate() {
        out[l].push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(k: usize, per: usize, dim: usize, sep: f32, rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for c in 0..k {
            let center: Vec<f32> =
                (0..dim).map(|j| if j % k == c { sep } else { 0.0 }).collect();
            for _ in 0..per {
                let p: Vec<f32> = center
                    .iter()
                    .map(|&v| v + rng.gaussian() as f32 * 0.1)
                    .collect();
                pts.push(p);
                truth.push(c);
            }
        }
        (pts, truth)
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let mut rng = Rng::new(1);
        let (pts, truth) = blobs(4, 20, 8, 5.0, &mut rng);
        let km = kmeans(&pts, 4, 50, &mut rng);
        // all points of a true blob share a predicted label
        for c in 0..4 {
            let labels: Vec<usize> = (0..pts.len())
                .filter(|&i| truth[i] == c)
                .map(|i| km.labels[i])
                .collect();
            assert!(labels.iter().all(|&l| l == labels[0]), "blob {c} split");
        }
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let mut rng = Rng::new(2);
        let (pts, _) = blobs(4, 25, 6, 3.0, &mut rng);
        let k2 = kmeans(&pts, 2, 50, &mut Rng::new(3));
        let k4 = kmeans(&pts, 4, 50, &mut Rng::new(3));
        assert!(k4.inertia < k2.inertia);
    }

    #[test]
    fn k_equals_n_gives_zero_inertia() {
        let mut rng = Rng::new(4);
        let pts: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32 * 2.0, -(i as f32)]).collect();
        let km = kmeans(&pts, 5, 20, &mut rng);
        assert!(km.inertia < 1e-9);
    }

    #[test]
    fn clusters_from_labels_partition() {
        let labels = vec![0, 2, 1, 0, 2];
        let cl = clusters_from_labels(&labels, 3);
        assert_eq!(cl[0], vec![0, 3]);
        assert_eq!(cl[1], vec![2]);
        assert_eq!(cl[2], vec![1, 4]);
    }

    #[test]
    fn handles_identical_points() {
        let pts = vec![vec![1.0f32, 1.0]; 6];
        let km = kmeans(&pts, 2, 10, &mut Rng::new(5));
        assert_eq!(km.labels.len(), 6);
        assert!(km.inertia < 1e-12);
    }
}
