//! Algorithm 2 — K-means-based device clustering.
//!
//! Every device trains an auxiliary model from a common initialization on
//! its local data; the cloud clusters the trained weight vectors with
//! K-means. Devices whose datasets share a majority class land in the same
//! cluster (ARI = 1 in Table II).
//!
//! Two auxiliary-model choices:
//! * **VKC**: the full HFL model `w⁰` (heavy — the Table II cost columns);
//! * **IKC**: the mini model ξ on 1×10×10 single-channel crops (~10 KB).
//!
//! The auxiliary training itself runs through [`Backend::local_round`]
//! (the AOT `local_round_<ds>` / `mini_local_round` artifacts on PJRT, the
//! pure-Rust kernels on the native backend), so this module is also the
//! Rust↔runtime integration point for Algorithm 2.
//!
//! Cost accounting (Table II): all N devices train in parallel at `f_max`
//! and upload over their geographically nearest edge with an equal B_m
//! split; edges forward the N weight vectors to the cloud. Compute cycles
//! scale with the auxiliary model's parameter count (cycles ∝ FLOPs ∝
//! params — DESIGN.md §5).

use super::ari::ari;
use super::kmeans::{clusters_from_labels, kmeans_restarts};
use crate::data::{DeviceData, Templates, NUM_CLASSES};
use crate::model::{init_params, Init};
use crate::runtime::Backend;
use crate::system::Topology;
use crate::util::Rng;

/// Which auxiliary model Algorithm 2 trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AuxModel {
    /// IKC: the ~10 KB mini model ξ on 10×10 crops.
    Mini,
    /// VKC: the full HFL model.
    Full,
}

impl AuxModel {
    /// Auxiliary-training learning rate for Algorithm 2. Empirically the
    /// majority-class direction dominates the weight delta from ≈0.5 on
    /// the mini model (ARI = 1.0, Table II); the full CNN diverges there,
    /// so VKC trains at a conventional rate.
    pub fn cluster_lr(self) -> f32 {
        match self {
            AuxModel::Mini => 0.5,
            AuxModel::Full => 0.05,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ClusteringResult {
    pub clusters: Vec<Vec<usize>>,
    pub labels: Vec<usize>,
    /// Wall-clock of Algorithm 2 in the *simulated* system (Table II col 1).
    pub time_s: f64,
    /// Energy of Algorithm 2 in the simulated system (Table II col 2).
    pub energy_j: f64,
    /// ARI vs the ground-truth majority classes (Table II col 3).
    pub ari: f64,
}

/// Crop a full image (C×img×img, channel 0) to a 1×10×10 mini-model input
/// with a deterministic per-sample offset.
pub fn crop_to_mini(full: &[f32], img: usize, key: u64, out: &mut [f32; 100]) {
    let mut rng = Rng::new(key ^ 0xc0ffee);
    let max_off = img - 10;
    let oy = rng.below(max_off + 1);
    let ox = rng.below(max_off + 1);
    for y in 0..10 {
        for x in 0..10 {
            out[y * 10 + x] = full[(oy + y) * img + (ox + x)];
        }
    }
}

/// Simulated delay/energy of Algorithm 2 (see module docs).
pub fn clustering_cost(topo: &Topology, aux_bits: f64, cycle_scale: f64) -> (f64, f64) {
    let p = &topo.params;
    // equal bandwidth split per nearest-edge population (nearest is the
    // O(1) construction-time cache, not a per-device O(M) rescan)
    let mut edge_pop = vec![0usize; topo.edges.len()];
    for n in 0..topo.n_devices() {
        edge_pop[topo.nearest_edge(n)] += 1;
    }

    let mut t_max = 0.0f64;
    let mut e_sum = 0.0f64;
    for n in 0..topo.n_devices() {
        let d = topo.device(n);
        let m = topo.nearest_edge(n);
        let b = topo.edges[m].bandwidth_hz / edge_pop[m] as f64;
        let cycles = p.local_iters as f64
            * d.cycles_per_sample
            * cycle_scale
            * d.num_samples as f64;
        let t_cmp = cycles / d.max_freq_hz;
        let e_cmp = 0.5 * p.alpha * cycles * d.max_freq_hz * d.max_freq_hz;
        let rate = topo.channel.rate(b, topo.gain(n, m), d.tx_power_w);
        let t_com = aux_bits / rate;
        t_max = t_max.max(t_cmp + t_com);
        e_sum += e_cmp + d.tx_power_w * t_com;
    }
    // edges forward all collected weight vectors to the cloud
    let mut t_fwd_max = 0.0f64;
    for e in &topo.edges {
        if edge_pop[e.id] == 0 {
            continue;
        }
        let rate = topo.channel.rate(p.cloud_bw_hz, e.gain_to_cloud, e.tx_power_w);
        let t_fwd = aux_bits * edge_pop[e.id] as f64 / rate;
        t_fwd_max = t_fwd_max.max(t_fwd);
        e_sum += e.tx_power_w * t_fwd;
    }
    (t_max + t_fwd_max, e_sum)
}

/// Run Algorithm 2: train the auxiliary model on every device (through the
/// backend's local-round kernel) and K-means the trained weights into K
/// clusters.
#[allow(clippy::too_many_arguments)]
pub fn cluster_devices(
    backend: &dyn Backend,
    topo: &Topology,
    templates: &Templates,
    device_data: &[DeviceData],
    aux: AuxModel,
    k: usize,
    lr: f32,
    rng: &mut Rng,
) -> anyhow::Result<ClusteringResult> {
    // Chain several local rounds so the auxiliary weight deltas integrate
    // enough local samples to be majority-class dominated (the paper's
    // full-batch eq. 1 sees D_n samples per step; our minibatch artifacts
    // see L·B — `rounds` closes that gap at negligible cost for ξ).
    let rounds: usize = match aux {
        AuxModel::Mini => 10,
        AuxModel::Full => 2,
    };
    let consts = backend.manifest().consts.clone();
    let (db, l, bsz) = (consts.db, consts.l, consts.b);
    let spec = templates.spec();
    let n = device_data.len();

    let (model_name, in_ch, img): (&str, usize, usize) = match aux {
        AuxModel::Mini => ("mini", 1, 10),
        AuxModel::Full => (spec.name.as_str(), spec.channels, spec.img),
    };
    let info = backend.manifest().model(model_name)?.clone();
    let p = info.params;

    // common initialization w_aux broadcast to every device (Alg.2 L2)
    let w_aux = init_params(&info, Init::HeNormal, rng);

    let pixels_in = in_ch * img * img;
    let full_pixels = spec.pixels();
    let mut weights: Vec<Vec<f32>> = Vec::with_capacity(n);

    let mut params_buf = vec![0.0f32; db * p];
    let mut xs = vec![0.0f32; db * l * bsz * pixels_in];
    let mut ys = vec![0.0f32; db * l * bsz * NUM_CLASSES];
    let mut full_buf = vec![0.0f32; full_pixels];

    let partial = backend.supports_partial_batch();
    for chunk in (0..n).collect::<Vec<_>>().chunks(db) {
        // build the device-slot batch (pad the tail with the last device
        // on fixed-shape backends; flexible ones take a short batch)
        let slots = if partial { chunk.len() } else { db };
        for slot in 0..slots {
            let dev = chunk.get(slot).cloned().unwrap_or(chunk[chunk.len() - 1]);
            let dd = &device_data[dev];
            params_buf[slot * p..(slot + 1) * p].copy_from_slice(&w_aux);
            for li in 0..l {
                for bi in 0..bsz {
                    let idx = rng.below(dd.n_samples);
                    let class = dd.gen(templates, idx, &mut full_buf);
                    let xoff =
                        ((slot * l + li) * bsz + bi) * pixels_in;
                    match aux {
                        AuxModel::Mini => {
                            let mut crop = [0.0f32; 100];
                            crop_to_mini(
                                &full_buf,
                                spec.img,
                                (dev as u64) << 32 | (li * bsz + bi) as u64,
                                &mut crop,
                            );
                            xs[xoff..xoff + 100].copy_from_slice(&crop);
                        }
                        AuxModel::Full => {
                            xs[xoff..xoff + pixels_in].copy_from_slice(&full_buf);
                        }
                    }
                    let yoff = ((slot * l + li) * bsz + bi) * NUM_CLASSES;
                    ys[yoff..yoff + NUM_CLASSES].fill(0.0);
                    ys[yoff + class] = 1.0;
                }
            }
        }
        let mut trained = params_buf[..slots * p].to_vec();
        for round in 0..rounds {
            if round > 0 {
                // fresh batches per round
                for slot in 0..slots {
                    let dev =
                        chunk.get(slot).cloned().unwrap_or(chunk[chunk.len() - 1]);
                    let dd = &device_data[dev];
                    for li in 0..l {
                        for bi in 0..bsz {
                            let idx = rng.below(dd.n_samples);
                            let class = dd.gen(templates, idx, &mut full_buf);
                            let xoff = ((slot * l + li) * bsz + bi) * pixels_in;
                            match aux {
                                AuxModel::Mini => {
                                    let mut crop = [0.0f32; 100];
                                    crop_to_mini(
                                        &full_buf,
                                        spec.img,
                                        (dev as u64) << 32
                                            | ((round * l + li) * bsz + bi) as u64,
                                        &mut crop,
                                    );
                                    xs[xoff..xoff + 100].copy_from_slice(&crop);
                                }
                                AuxModel::Full => {
                                    xs[xoff..xoff + pixels_in]
                                        .copy_from_slice(&full_buf);
                                }
                            }
                            let yoff = ((slot * l + li) * bsz + bi) * NUM_CLASSES;
                            ys[yoff..yoff + NUM_CLASSES].fill(0.0);
                            ys[yoff + class] = 1.0;
                        }
                    }
                }
            }
            let (updated, _losses) = backend.local_round(
                model_name,
                &trained,
                &xs[..slots * l * bsz * pixels_in],
                &ys[..slots * l * bsz * NUM_CLASSES],
                lr,
            )?;
            trained = updated;
        }
        for (slot, &dev) in chunk.iter().enumerate() {
            let _ = dev;
            weights.push(trained[slot * p..(slot + 1) * p].to_vec());
        }
    }

    // Cloud-side K-means over trained weight deltas, with three standard
    // sharpenings of the raw-weights clustering: subtract the common init
    // (pure gradient direction), restrict to the classifier-head leaves
    // (the majority class manifests as "push my class logit up" — feature-
    // extractor deltas mostly carry shared task signal + minibatch noise),
    // and L2-normalize each delta (data volume scales step length, not
    // direction).
    let head: Vec<(usize, usize)> = info
        .leaves
        .iter()
        .filter(|lf| lf.name.starts_with("fc"))
        .map(|lf| (lf.offset, lf.size))
        .collect();
    let deltas: Vec<Vec<f32>> = weights
        .iter()
        .map(|w| {
            let mut d: Vec<f32> = head
                .iter()
                .flat_map(|&(off, size)| {
                    (off..off + size).map(|i| w[i] - w_aux[i])
                })
                .map(|x| if x.is_finite() { x } else { 0.0 })
                .collect();
            let norm = d.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                for x in d.iter_mut() {
                    *x /= norm;
                }
            }
            d
        })
        .collect();
    let km = kmeans_restarts(&deltas, k, 100, 20, rng);
    let clusters = clusters_from_labels(&km.labels, k);

    let truth: Vec<usize> = device_data.iter().map(|d| d.majority).collect();
    let ari_v = ari(&km.labels, &truth);

    let hfl_params = backend.manifest().model(spec.name.as_str())?.params;
    let cycle_scale = p as f64 / hfl_params as f64;
    let aux_bits = (info.bytes * 8) as f64;
    let (time_s, energy_j) = match aux {
        AuxModel::Mini => clustering_cost(topo, aux_bits, cycle_scale),
        AuxModel::Full => clustering_cost(topo, aux_bits, 1.0),
    };

    Ok(ClusteringResult { clusters, labels: km.labels, time_s, energy_j, ari: ari_v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::SystemParams;

    #[test]
    fn crop_is_deterministic_and_in_bounds() {
        let img = 28;
        let full: Vec<f32> = (0..img * img).map(|i| i as f32).collect();
        let mut a = [0.0f32; 100];
        let mut b = [0.0f32; 100];
        crop_to_mini(&full, img, 7, &mut a);
        crop_to_mini(&full, img, 7, &mut b);
        assert_eq!(a, b);
        // all values must come from the source image
        assert!(a.iter().all(|&v| v >= 0.0 && v < (img * img) as f32));
        // rows are contiguous runs from the source
        assert_eq!(a[1] - a[0], 1.0);
        assert_eq!(a[10] - a[0], img as f32);
    }

    #[test]
    fn clustering_cost_scales_with_model_size() {
        let topo = Topology::generate(&SystemParams::default(), &mut Rng::new(1));
        let (t_small, e_small) = clustering_cost(&topo, 10.0 * 1024.0 * 8.0, 0.02);
        let (t_big, e_big) = clustering_cost(&topo, 448.0 * 1024.0 * 8.0, 1.0);
        assert!(t_big > 10.0 * t_small, "{t_big} vs {t_small}");
        assert!(e_big > 10.0 * e_small, "{e_big} vs {e_small}");
    }

    #[test]
    fn clustering_cost_positive_finite() {
        let topo = Topology::generate(&SystemParams::default(), &mut Rng::new(2));
        let (t, e) = clustering_cost(&topo, 1e5, 0.1);
        assert!(t.is_finite() && t > 0.0);
        assert!(e.is_finite() && e > 0.0);
    }
}
