//! Device scheduling (§IV): select the subset `H_i` of devices that joins
//! each global iteration.
//!
//! * [`schedulers::FedAvg`] — uniform random baseline [3].
//! * [`schedulers::Vkc`] — vanilla K-Center (Algorithm 3).
//! * [`schedulers::Ikc`] — improved K-Center (Algorithm 4), the paper's
//!   scheduling contribution.
//! * [`clustering`] — Algorithm 2 (auxiliary-model K-means clustering).
//! * [`ari`] — the Adjusted Rand Index (eq. 28) used by Table II.

pub mod ari;
pub mod clustering;
pub mod kmeans;
pub mod schedulers;

pub use ari::ari;
pub use clustering::{cluster_devices, AuxModel, ClusteringResult};
pub use kmeans::{clusters_from_labels, kmeans, kmeans_restarts, KMeans};
pub use schedulers::{FedAvg, Ikc, Scheduler, Vkc};
