//! Adjusted Rand Index (eq. 28) — the clustering accuracy criterion of
//! Table II.
//!
//! The paper states the pair-counting form
//! `ARI = 2(σ00·σ11 − σ01·σ10) / [(σ00+σ01)(σ01+σ11) + (σ00+σ10)(σ10+σ11)]`
//! over pairs that agree/disagree between prediction and ground truth.

/// σ counts over all unordered pairs.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PairCounts {
    /// same cluster in both.
    pub s11: f64,
    /// different clusters in both.
    pub s00: f64,
    /// same in prediction, different in truth.
    pub s01: f64,
    /// different in prediction, same in truth.
    pub s10: f64,
}

pub fn pair_counts(pred: &[usize], truth: &[usize]) -> PairCounts {
    assert_eq!(pred.len(), truth.len());
    let n = pred.len();
    let mut c = PairCounts::default();
    for i in 0..n {
        for j in (i + 1)..n {
            let same_p = pred[i] == pred[j];
            let same_t = truth[i] == truth[j];
            match (same_p, same_t) {
                (true, true) => c.s11 += 1.0,
                (false, false) => c.s00 += 1.0,
                (true, false) => c.s01 += 1.0,
                (false, true) => c.s10 += 1.0,
            }
        }
    }
    c
}

/// ARI per eq. 28. 1.0 = identical clusterings, ≈0 = chance agreement.
pub fn ari(pred: &[usize], truth: &[usize]) -> f64 {
    let c = pair_counts(pred, truth);
    let num = 2.0 * (c.s00 * c.s11 - c.s01 * c.s10);
    let den = (c.s00 + c.s01) * (c.s01 + c.s11) + (c.s00 + c.s10) * (c.s10 + c.s11);
    if den == 0.0 {
        1.0 // degenerate: a single cluster in both — perfect agreement
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_clusterings_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn label_permutation_invariant() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let pred = vec![2, 2, 0, 0, 1, 1];
        assert!((ari(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_assignment_near_zero() {
        // deterministic pseudo-random labels vs structured truth
        let truth: Vec<usize> = (0..200).map(|i| i / 20).collect();
        let pred: Vec<usize> =
            (0..200).map(|i| (i * 7919 + 13) % 10).collect();
        let v = ari(&pred, &truth);
        assert!(v.abs() < 0.1, "{v}");
    }

    #[test]
    fn partial_agreement_between_zero_and_one() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        let pred = vec![0, 0, 1, 1, 1, 1];
        let v = ari(&pred, &truth);
        assert!(v > 0.0 && v < 1.0, "{v}");
    }

    #[test]
    fn pair_counts_sum_to_n_choose_2() {
        let truth = vec![0, 1, 0, 2, 1];
        let pred = vec![1, 1, 0, 0, 2];
        let c = pair_counts(&pred, &truth);
        assert_eq!(c.s00 + c.s01 + c.s10 + c.s11, 10.0);
    }
}
