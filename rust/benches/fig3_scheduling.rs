//! Bench for Fig. 3: scheduling-quality comparison at reduced scale
//! (IKC vs VKC vs FedAvg accuracy after a fixed iteration budget on
//! synth-fmnist). The full curves come from `hfl exp fig3`.

use hfl::bench::bench_once;
use hfl::config::Config;
use hfl::experiments::fig_sched;
use hfl::runtime::Engine;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let mut cfg = Config::default();
    cfg.seeds = 1;
    cfg.max_iters = 3;
    cfg.test_size = 300;
    cfg.h_values = vec![30];
    cfg.out_dir = std::env::temp_dir().join("hfl_bench_f3").display().to_string();
    let (curves, _) = bench_once("fig3/3_iters_h30_all_schedulers", || {
        fig_sched::run(&engine, &cfg, "fmnist").unwrap()
    });
    for c in &curves {
        println!(
            "  {}: acc after {} iters = {:.3}",
            c.scheduler,
            c.mean.len(),
            c.mean.last().unwrap_or(&0.0)
        );
    }
}
