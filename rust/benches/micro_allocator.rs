//! Micro-bench: the convex resource allocator (problem 27) — the inner
//! loop of HFEL and of every per-iteration cost evaluation.

use hfl::allocation::{solve_edge, SolverOpts};
use hfl::bench::bench;
use hfl::system::{SystemParams, Topology};
use hfl::util::Rng;

fn main() {
    let topo = Topology::generate(&SystemParams::default(), &mut Rng::new(1));
    for n in [1usize, 5, 10, 20] {
        let devices: Vec<usize> = (0..n).collect();
        bench(&format!("alloc/default/n={n}"), 3, 20, || {
            let s = solve_edge(&topo, 0, &devices, 1.0, &SolverOpts::default());
            std::hint::black_box(s.objective);
        });
        bench(&format!("alloc/fast/n={n}"), 3, 20, || {
            let s = solve_edge(&topo, 0, &devices, 1.0, &SolverOpts::fast());
            std::hint::black_box(s.objective);
        });
    }
}
