//! Bench for Fig. 7: one full-framework global iteration (IKC + D³QN +
//! allocator + Algorithm 1 training) end to end — the system's composite
//! latency unit.

use hfl::allocation::SolverOpts;
use hfl::assignment::drl::DrlAssigner;
use hfl::bench::{bench, bench_once};
use hfl::fl::{HflConfig, HflTrainer};
use hfl::model::{init_params, Init};
use hfl::runtime::Engine;
use hfl::scheduling::{FedAvg, Scheduler};
use hfl::assignment::Assigner;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let cfg = HflConfig {
        dataset: "fmnist".into(),
        h: 50,
        lr: 0.05,
        target_acc: 1.0,
        max_iters: 1,
        test_size: 300,
        frac_major: 0.8,
        seed: 3,
    };
    let mut trainer = HflTrainer::with_default_topology(&engine, cfg).unwrap();
    let mut sched = FedAvg::new(100, 50, 1);
    let mut drl = DrlAssigner::fresh(&engine, 1).unwrap();

    // end-to-end global iteration (schedule→assign→allocate→train→eval)
    let (_, dt) = bench_once("fig7/one_global_iteration_h50", || {
        trainer
            .run(&mut sched, &mut drl, &SolverOpts::default(), |_| {})
            .unwrap()
    });
    println!("  -> {:.2}s per global iteration at H=50", dt);

    // isolated pieces
    let info = engine.manifest.model("fmnist").unwrap().clone();
    let mut rng = hfl::util::Rng::new(9);
    let global = init_params(&info, Init::HeNormal, &mut rng);
    let scheduled = sched.schedule();
    let assignment = drl.assign(&trainer.topo, &scheduled);
    bench("fig7/algorithm1_training_only_h50", 0, 2, || {
        let (p, _) = trainer
            .train_global_iteration(&global, &assignment)
            .unwrap();
        std::hint::black_box(p.len());
    });
}
