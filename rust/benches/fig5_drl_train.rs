//! Bench for Fig. 5: D³QN training throughput (episodes/s) at a reduced
//! episode count — the full curve is produced by `hfl exp fig5`. Runs on
//! the native backend (BPTT + Adam in pure Rust), so it needs no AOT
//! artifacts.

use hfl::bench::bench_once;
use hfl::drl::{DqnTrainConfig, DqnTrainer};
use hfl::runtime::{Backend, NativeBackend};

fn main() {
    let backend = NativeBackend::new();
    let mut cfg = DqnTrainConfig::default();
    cfg.episodes = 6;
    cfg.hfel_exchange = 100;
    cfg.system.model_bits =
        (backend.manifest().model("fmnist").unwrap().bytes * 8) as f64;
    let mut tr = DqnTrainer::new(&backend, cfg).unwrap();
    let (res, dt) = bench_once("fig5/drl_train_6_episodes", || tr.train(|_, _| {}).unwrap());
    println!(
        "  {:.1}s/episode, {} train steps, mean reward {:.1}",
        dt / 6.0,
        res.losses.len(),
        res.episode_rewards.iter().sum::<f64>() / res.episode_rewards.len() as f64
    );
}
