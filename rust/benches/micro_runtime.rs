//! Micro-bench: PJRT artifact dispatch — local_round (the L3→L1 hot path),
//! eval, and D³QN q_all inference (the per-iteration assignment call).

use hfl::bench::bench;
use hfl::data::{partition, SynthSpec, Templates, NUM_CLASSES};
use hfl::model::{init_params, Init};
use hfl::runtime::{Arg, Engine};
use hfl::util::Rng;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let c = engine.manifest.consts.clone();
    let info = engine.manifest.model("fmnist").unwrap().clone();
    let spec = SynthSpec::fmnist();
    let templates = Templates::generate(&spec, 1);
    let dd = partition(c.db, &vec![500; c.db], 0.8, 1);
    let mut rng = Rng::new(2);

    let (db, l, b, p) = (c.db, c.l, c.b, info.params);
    let pixels = spec.pixels();
    let base = init_params(&info, Init::HeNormal, &mut rng);
    let mut params = vec![0.0f32; db * p];
    for s in 0..db {
        params[s * p..(s + 1) * p].copy_from_slice(&base);
    }
    let mut xs = vec![0.0f32; db * l * b * pixels];
    let mut ys = vec![0.0f32; db * l * b * NUM_CLASSES];
    for s in 0..db {
        dd[s].fill_batch(&templates, &mut rng, l * b,
            &mut xs[s * l * b * pixels..(s + 1) * l * b * pixels],
            &mut ys[s * l * b * NUM_CLASSES..(s + 1) * l * b * NUM_CLASSES]);
    }
    let r = bench("runtime/local_round_fmnist(db=8,l=5,b=8)", 2, 10, || {
        let out = engine.run("local_round_fmnist", &[
            Arg::F32(&params, &[db as i64, p as i64]),
            Arg::F32(&xs, &[db as i64, l as i64, b as i64, 1, 28, 28]),
            Arg::F32(&ys, &[db as i64, l as i64, b as i64, NUM_CLASSES as i64]),
            Arg::ScalarF32(0.01),
        ]).unwrap();
        std::hint::black_box(out[1][0]);
    });
    // device-rounds per second (each call trains DB devices for L steps)
    println!("  -> {:.1} device-rounds/s", db as f64 * r.throughput_per_s());

    let eb = c.eb;
    let xe = vec![0.1f32; eb * pixels];
    bench("runtime/eval_fmnist(eb)", 2, 10, || {
        let out = engine.run("eval_fmnist", &[
            Arg::F32(&base, &[p as i64]),
            Arg::F32(&xe, &[eb as i64, 1, 28, 28]),
        ]).unwrap();
        std::hint::black_box(out[0][0]);
    });

    let qinfo = engine.manifest.model("dqn").unwrap().clone();
    let theta = init_params(&qinfo, Init::GlorotUniform, &mut rng);
    let h = c.train_horizon;
    let feats = vec![0.5f32; h * c.feat];
    bench("runtime/dqn_q_all_h50 (full-iteration assignment)", 2, 20, || {
        let out = engine.run(&format!("dqn_q_all_h{h}"), &[
            Arg::F32(&theta, &[theta.len() as i64]),
            Arg::F32(&feats, &[h as i64, c.feat as i64]),
        ]).unwrap();
        std::hint::black_box(out[0][0]);
    });
}
