//! Bench for Fig. 6(d): assignment latency per strategy — the paper's
//! headline D³QN-vs-HFEL speed claim.

use hfl::assignment::drl::DrlAssigner;
use hfl::assignment::geo::Geographic;
use hfl::assignment::hfel::Hfel;
use hfl::assignment::Assigner;
use hfl::bench::bench;
use hfl::runtime::Engine;
use hfl::system::{SystemParams, Topology};
use hfl::util::Rng;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let mut sys = SystemParams::default();
    sys.n_devices = 50;
    sys.model_bits = (engine.manifest.model("fmnist").unwrap().bytes * 8) as f64;
    let topo = Topology::generate(&sys, &mut Rng::new(1));
    let scheduled: Vec<usize> = (0..50).collect();

    let drl = DrlAssigner::fresh(&engine, 1).unwrap();
    // warm up the executable cache so we measure the request path
    let _ = drl.assign_with_q(&topo, &scheduled).unwrap();
    bench("assign/d3qn(H=50)", 2, 30, || {
        let (a, _) = drl.assign_with_q(&topo, &scheduled).unwrap();
        std::hint::black_box(a.num_devices());
    });
    bench("assign/geographic(H=50)", 2, 30, || {
        let a = Geographic.assign(&topo, &scheduled);
        std::hint::black_box(a.num_devices());
    });
    bench("assign/hfel-100(H=50)", 0, 3, || {
        let a = Hfel::new(100, 7).run(&topo, &scheduled);
        std::hint::black_box(a.num_devices());
    });
    bench("assign/hfel-300(H=50)", 0, 3, || {
        let a = Hfel::new(300, 7).run(&topo, &scheduled);
        std::hint::black_box(a.num_devices());
    });
}
