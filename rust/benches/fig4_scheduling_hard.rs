//! Bench for Fig. 4: same as fig3_scheduling but on the harder
//! synth-cifar dataset (3×32×32, heavier noise + mixing + jitter).

use hfl::bench::bench_once;
use hfl::config::Config;
use hfl::experiments::fig_sched;
use hfl::runtime::Engine;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let mut cfg = Config::default();
    cfg.seeds = 1;
    cfg.max_iters = 2;
    cfg.test_size = 300;
    cfg.h_values = vec![30];
    cfg.out_dir = std::env::temp_dir().join("hfl_bench_f4").display().to_string();
    let (curves, _) = bench_once("fig4/2_iters_h30_all_schedulers_cifar", || {
        fig_sched::run(&engine, &cfg, "cifar").unwrap()
    });
    for c in &curves {
        println!(
            "  {}: acc after {} iters = {:.3}",
            c.scheduler,
            c.mean.len(),
            c.mean.last().unwrap_or(&0.0)
        );
    }
}
