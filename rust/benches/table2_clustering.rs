//! Bench for Table II: wall-clock of Algorithm 2 with the mini model ξ
//! (IKC) vs the full model (VKC) — plus the simulated system costs.

use hfl::bench::bench_once;
use hfl::config::Config;
use hfl::experiments::table2;
use hfl::runtime::Engine;

fn main() {
    let engine = Engine::open(std::path::Path::new("artifacts")).expect("make artifacts");
    let mut cfg = Config::default();
    cfg.out_dir = std::env::temp_dir().join("hfl_bench_t2").display().to_string();
    let (rows, _) = bench_once("table2/algorithm2_all_methods", || {
        table2::run(&engine, &cfg).unwrap()
    });
    for r in &rows {
        println!(
            "  {}: simulated {:.1}s / {:.1}J, ARI {:.2}",
            r.method, r.result.time_s, r.result.energy_j, r.result.ari
        );
    }
}
