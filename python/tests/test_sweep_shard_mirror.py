"""Toolchain-less oracle for the sweep shard/merge/resume logic (ISSUE 5).

A literal Python transcription of the orchestration-layer algorithms in
`rust/src/scenario/{plan,merge}.rs` — the manifest format, the
round-robin shard split, the byte-offset resume cookie (truncate to the
last recorded cut, re-deliver the rest) and the k-way leading-cell-id
merge — exercised over randomized grids and crash points. When no Rust
toolchain is available (see .claude/skills/verify/SKILL.md), a change to
that logic should be mirrored here first: an algorithmic bug (overlap /
gap in the shard partition, wrong merge interleave, resume double-write)
fails these tests without ever compiling the Rust.

Stdlib only (no numpy).
"""
import random

# ---------------- sink output mirror ----------------
# Synthetic but structurally faithful rows: every line starts with the
# cell id (CSV first column / JSONL "cell" key), rows of a cell are
# consecutive, cells ascend. Values depend only on (cell, iter) so any
# execution order writes identical bytes, like the Rust cell RNG streams.

CSV_HEADER = "cell,scheduler,assigner,h,seed,iter,t_i\n"


def cell_rows_csv(cell_id, iters):
    return "".join(
        f"{cell_id},sched{cell_id % 3},assign{cell_id % 2},10,0,{it},{(cell_id * 7 + it):.6f}\n"
        for it in range(iters)
    )


def cell_summary_csv(cell_id, iters):
    return f"{cell_id},sched{cell_id % 3},assign{cell_id % 2},10,0,{iters},{cell_id * 7.0:.6f}\n"


def cell_rows_jsonl(cell_id, iters):
    return "".join(
        f'{{"cell":{cell_id},"iter":{it},"t_i":{(cell_id * 7 + it):.6f}}}\n'
        for it in range(iters)
    )


class Sink:
    """CsvSink/JsonlSink mirror: append-only string with offset cookies."""

    def __init__(self, header):
        self.buf = header

    def checkpoint(self):
        return len(self.buf)

    def restore(self, cookie):
        self.buf = self.buf[:cookie]


def run_shard(cells, iters, make_block, sink, manifest, resume=False, abort_after=None):
    """plan.rs run loop: skip the manifest prefix, restore the cookie,
    deliver in plan order, record (id, cookie) per delivered cell."""
    skip = 0
    if resume and manifest["lines"]:
        skip = len(manifest["lines"])
        assert [i for i, _ in manifest["lines"]] == cells[:skip]
        sink.restore(manifest["lines"][-1][1])
    elif resume:
        sink.restore(manifest["start"])
    run = 0
    for cell in cells[skip:]:
        if abort_after is not None and run >= abort_after:
            return True
        sink.buf += make_block(cell, iters)
        manifest["lines"].append((cell, sink.checkpoint()))
        run += 1
    return False


def shard_cells(total, i, n):
    return [c for c in range(total) if c % n == i]


# ---------------- merge.rs mirror ----------------

def line_cell_id(line):
    if line.startswith('{"cell":'):
        rest = line[len('{"cell":'):]
        digits = ""
        for ch in rest:
            if ch.isdigit():
                digits += ch
            else:
                break
        return int(digits)
    return int(line.split(",")[0])


def merge_streams(shard_texts, has_header, total_cells):
    streams = []
    header = None
    for text in shard_texts:
        lines = text.splitlines(keepends=True)
        if has_header:
            h, lines = lines[0], lines[1:]
            assert header is None or header == h
            header = h
        streams.append(lines)
    out = header or ""
    pos = [0] * len(streams)
    for expect in range(total_cells):
        si = next(
            (
                k
                for k, lines in enumerate(streams)
                if pos[k] < len(lines) and line_cell_id(lines[pos[k]]) == expect
            ),
            None,
        )
        assert si is not None, f"cell {expect} missing from every shard"
        while pos[si] < len(streams[si]) and line_cell_id(streams[si][pos[si]]) == expect:
            out += streams[si][pos[si]]
            pos[si] += 1
    for k, lines in enumerate(streams):
        assert pos[k] == len(lines), "leftover lines after merge"
    return out


# ---------------- properties ----------------

def single_shot(total, iters, make_block, header):
    s = Sink(header)
    m = {"start": s.checkpoint(), "lines": []}
    run_shard(list(range(total)), iters, make_block, s, m)
    return s.buf


def test_shard_split_partitions_ids():
    rng = random.Random(5)
    for _ in range(50):
        total = rng.randrange(1, 40)
        n = rng.randrange(1, 8)
        seen = []
        for i in range(n):
            cells = shard_cells(total, i, n)
            assert cells == sorted(cells)
            seen += cells
        assert sorted(seen) == list(range(total))


def test_any_partition_merges_to_single_shot_bytes():
    rng = random.Random(7)
    for _ in range(30):
        total = rng.randrange(1, 30)
        iters = rng.randrange(1, 4)
        n = rng.randrange(1, 6)
        for make_block, header, has_header in [
            (cell_rows_csv, CSV_HEADER, True),
            (cell_summary_csv, CSV_HEADER, True),
            (cell_rows_jsonl, "", False),
        ]:
            want = single_shot(total, iters, make_block, header)
            shard_texts = []
            order = list(range(n))
            rng.shuffle(order)  # shards finish in any order
            for i in order:
                s = Sink(header)
                m = {"start": s.checkpoint(), "lines": []}
                run_shard(shard_cells(total, i, n), iters, make_block, s, m)
                shard_texts.append(s.buf)
            # merge consults ids, not shard order
            got = merge_streams(shard_texts, has_header, total)
            assert got == want, f"total={total} n={n} {make_block.__name__}"


def test_resume_after_abort_is_byte_identical():
    rng = random.Random(11)
    for _ in range(40):
        total = rng.randrange(2, 25)
        iters = rng.randrange(1, 4)
        cells = list(range(total))
        want = single_shot(total, iters, cell_rows_csv, CSV_HEADER)

        s = Sink(CSV_HEADER)
        m = {"start": s.checkpoint(), "lines": []}
        cut = rng.randrange(0, total)
        aborted = run_shard(cells, iters, cell_rows_csv, s, m, abort_after=cut)
        assert aborted == (cut < total)
        run_shard(cells, iters, cell_rows_csv, s, m, resume=True)
        assert s.buf == want


def test_crash_tail_is_discarded_by_the_cookie_restore():
    rng = random.Random(13)
    for _ in range(40):
        total = rng.randrange(1, 20)
        iters = rng.randrange(1, 4)
        cells = list(range(total))
        want = single_shot(total, iters, cell_rows_csv, CSV_HEADER)

        s = Sink(CSV_HEADER)
        m = {"start": s.checkpoint(), "lines": []}
        cut = rng.randrange(0, total)
        run_shard(cells, iters, cell_rows_csv, s, m, abort_after=cut)
        # crash mid-cell: rows (possibly partial) written, no manifest line
        orphan = cell_rows_csv(cut, iters)[: rng.randrange(1, 8)]
        s.buf += orphan
        run_shard(cells, iters, cell_rows_csv, s, m, resume=True)
        assert s.buf == want


def test_resume_with_zero_completed_cells_restores_to_start():
    # crash after the header + manifest header, before any cell
    s = Sink(CSV_HEADER)
    m = {"start": s.checkpoint(), "lines": []}
    s.buf += "0,partial"
    run_shard([0, 1], 2, cell_rows_csv, s, m, resume=True)
    assert s.buf == single_shot(2, 2, cell_rows_csv, CSV_HEADER)


def test_merge_detects_missing_cells():
    import pytest

    s0 = Sink(CSV_HEADER)
    m0 = {"start": s0.checkpoint(), "lines": []}
    run_shard(shard_cells(4, 0, 2), 1, cell_rows_csv, s0, m0)
    # shard 1 missing entirely
    with pytest.raises(AssertionError, match="missing"):
        merge_streams([s0.buf], True, 4)


def test_jsonl_and_csv_leading_ids_agree():
    for cell_id in [0, 7, 123]:
        assert line_cell_id(cell_rows_csv(cell_id, 1)) == cell_id
        assert line_cell_id(cell_rows_jsonl(cell_id, 1)) == cell_id
