"""Toolchain-less oracle for the `hfl top` tailer (ISSUE 10).

A literal Python transcription of `rust/src/fleet/tail.rs` — the
torn-write-safe incremental reader `hfl top` uses on live sweep outputs:
only newline-terminated bytes are consumed, the consumed offset is
remembered between polls, UTF-8 is validated only over terminated lines,
and a file that SHRANK below the remembered offset (a `--resume`
truncating a crash tail) rewinds to zero and tells the caller to discard
accumulated state. When no Rust toolchain is available (see
.claude/skills/verify/SKILL.md), a change to that logic should be
mirrored here first: an off-by-one in the consume point or a missed
rewind fails these tests without ever compiling the Rust.

Stdlib only (no numpy).
"""
import io
import json
import os
import random
import tempfile
import unittest


class Tailer:
    """Mirror of fleet::tail::Tailer. poll() -> (lines, rewound)."""

    def __init__(self, path):
        self.path = path
        self.offset = 0  # bytes consumed, always at a line boundary

    def poll(self):
        lines, rewound = [], False
        try:
            f = open(self.path, "rb")
        except FileNotFoundError:
            return lines, rewound
        with f:
            f.seek(0, io.SEEK_END)
            length = f.tell()
            if length < self.offset:
                # resume truncated the file under us
                self.offset = 0
                rewound = True
            if length == self.offset:
                return lines, rewound
            f.seek(self.offset)
            buf = f.read()
        # consume only through the last newline; the torn tail (possibly
        # mid-UTF-8) stays for a future poll
        cut = buf.rfind(b"\n")
        if cut < 0:
            return lines, rewound
        consumed = buf[: cut + 1]
        text = consumed.decode("utf-8")  # error only on terminated lines
        self.offset += len(consumed)
        lines.extend(l.rstrip("\r") for l in text.split("\n")[:-1])
        return lines, rewound


def jsonl_stream(cells=6, iters=3):
    """A structurally faithful JSONL row stream (ascii + one unicode key)."""
    out = []
    for c in range(cells):
        for it in range(iters):
            out.append(
                json.dumps(
                    {
                        "cell": c,
                        "scheduler": "ikc" if c % 2 else "vkcé",  # é: 2-byte UTF-8
                        "iter": it,
                        "objective": round(c * 7.0 + it, 6),
                    },
                    separators=(",", ":"),
                )
            )
    return ("\n".join(out) + "\n").encode("utf-8")


class TailerMirrorTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.mkdtemp(prefix="hfl_tail_mirror_")
        self.path = os.path.join(self.dir, "rows.jsonl")

    def test_missing_file_is_empty_not_an_error(self):
        lines, rewound = Tailer(os.path.join(self.dir, "never")).poll()
        self.assertEqual(lines, [])
        self.assertFalse(rewound)

    def test_consumes_only_terminated_lines(self):
        with open(self.path, "wb") as f:
            f.write(b'{"cell":0}\n{"cell":1')
        t = Tailer(self.path)
        lines, _ = t.poll()
        self.assertEqual(lines, ['{"cell":0}'])
        self.assertEqual(t.offset, 11)
        with open(self.path, "ab") as f:
            f.write(b"}\n")
        lines, _ = t.poll()
        self.assertEqual(lines, ['{"cell":1}'])
        self.assertEqual(t.poll(), ([], False))

    def test_adversarial_chunk_splits_never_tear_lines(self):
        """The tentpole property: for ANY chunking of a real byte stream —
        including splits inside multi-byte UTF-8 sequences — no poll yields
        a partial line, and the concatenation is exactly the stream."""
        full = jsonl_stream()
        want = full.decode("utf-8").splitlines()
        rng = random.Random(31)
        schedules = [[1], [2, 3, 5, 7, 11]] + [
            [rng.randint(1, 17) for _ in range(64)] for _ in range(20)
        ]
        for sizes in schedules:
            with open(self.path, "wb"):
                pass
            t = Tailer(self.path)
            got, i, si = [], 0, 0
            while i < len(full):
                n = min(sizes[si % len(sizes)], len(full) - i)
                si += 1
                with open(self.path, "ab") as f:
                    f.write(full[i : i + n])
                i += n
                lines, rewound = t.poll()
                self.assertFalse(rewound)
                for line in lines:
                    json.loads(line)  # torn line would fail to parse
                    got.append(line)
            self.assertEqual(got, want, f"chunk schedule {sizes} tore lines")
            self.assertEqual(t.offset, len(full))

    def test_mid_utf8_tear_is_never_yielded(self):
        # "é" = 0xC3 0xA9; cut between the bytes after a terminated line
        with open(self.path, "wb") as f:
            f.write(b"ok\n\xc3")
        t = Tailer(self.path)
        lines, _ = t.poll()
        self.assertEqual(lines, ["ok"])
        self.assertEqual(t.offset, 3)
        with open(self.path, "ab") as f:
            f.write(b"\xa9x\n")
        lines, _ = t.poll()
        self.assertEqual(lines, ["éx"])

    def test_shrunken_file_rewinds_and_replays(self):
        with open(self.path, "wb") as f:
            f.write(b"a\nb\nc\n")
        t = Tailer(self.path)
        lines, _ = t.poll()
        self.assertEqual(lines, ["a", "b", "c"])
        # a resume truncated back past our offset
        with open(self.path, "wb") as f:
            f.write(b"a\n")
        lines, rewound = t.poll()
        self.assertTrue(rewound, "shrink must signal a rewind")
        self.assertEqual(lines, ["a"])
        self.assertEqual(t.offset, 2)

    def test_same_length_rewrite_is_not_a_rewind(self):
        # the rewind heuristic is length-based (like the Rust); equal-length
        # rewrites are indistinguishable and must at least not duplicate
        with open(self.path, "wb") as f:
            f.write(b"a\nb\n")
        t = Tailer(self.path)
        t.poll()
        lines, rewound = t.poll()
        self.assertEqual((lines, rewound), ([], False))


if __name__ == "__main__":
    unittest.main()
