"""L2 correctness: D³QN BiLSTM agent + double-DQN/Adam train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dqn
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = dqn.DqnConfig(n_edges=3, horizon=6, hid=8, fc=8)


def theta_for(seed=0, cfg=CFG):
    return dqn.init_flat(jax.random.PRNGKey(seed), cfg)


def feats_for(seed=1, cfg=CFG):
    return jax.random.uniform(jax.random.PRNGKey(seed),
                              (cfg.horizon, cfg.feat), jnp.float32)


def qvalues_ref(flat, feats, cfg):
    """Oracle: per-t explicit prefix/suffix LSTM runs with jnp ops."""
    p = dqn.unflatten(flat, cfg)

    def run(seq):
        h = jnp.zeros((1, cfg.hid), jnp.float32)
        c = jnp.zeros((1, cfg.hid), jnp.float32)
        for x in seq:
            h, c = ref.lstm_cell_ref(x[None, :], h, c,
                                     p["lstm_wi"], p["lstm_wh"], p["lstm_b"])
        return h[0]

    rows = []
    for t in range(cfg.horizon):
        hf = run(feats[: t + 1])                 # forward input χ_1..χ_t
        hb = run(feats[t:][::-1])                # backward input χ_t..χ_H
        hcat = jnp.concatenate([hf, hb])[None, :]
        trunk = jnp.maximum(hcat @ p["fc_w"] + p["fc_b"], 0.0)
        v = trunk @ p["v_w"] + p["v_b"]
        a = trunk @ p["a_w"] + p["a_b"]
        rows.append((v + a - a.mean(axis=-1, keepdims=True))[0])
    return jnp.stack(rows)


def test_qvalues_all_matches_per_t_oracle():
    flat, feats = theta_for(), feats_for()
    got = dqn.qvalues_all(flat, feats, CFG)
    want = qvalues_ref(flat, feats, CFG)
    assert got.shape == (CFG.horizon, CFG.n_edges)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qvalues_dueling_identity():
    """Q - V must be mean-zero across actions (dueling eq. 20)."""
    flat, feats = theta_for(2), feats_for(3)
    q = dqn.qvalues_all(flat, feats, CFG)
    p = dqn.unflatten(flat, CFG)
    # mean over actions equals V: A - mean(A) cancels
    # recompute V through the oracle trunk
    want_v = qvalues_ref(flat, feats, CFG).mean(axis=-1)
    np.testing.assert_allclose(q.mean(axis=-1), want_v, rtol=1e-4, atol=1e-4)


def test_param_count_matches_layout():
    n = dqn.param_count(CFG)
    assert dqn.init_flat(jax.random.PRNGKey(0), CFG).shape == (n,)


def _batch(o=4, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    feats_b = jax.random.uniform(k1, (o, CFG.horizon, CFG.feat), jnp.float32)
    t_b = jax.random.randint(k2, (o,), 0, CFG.horizon)
    a_b = jax.random.randint(k3, (o,), 0, CFG.n_edges)
    r_b = jnp.where(jax.random.uniform(k4, (o,)) > 0.5, 1.0, -1.0)
    done_b = (t_b == CFG.horizon - 1).astype(jnp.float32)
    return feats_b, t_b, a_b, r_b, done_b


def test_train_step_reduces_td_loss():
    flat = theta_for()
    tgt = theta_for(9)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step = jnp.float32(0.0)
    batch = _batch(o=8)
    fn = jax.jit(dqn.make_train_step(CFG, lr=5e-3))
    loss_first = None
    for _ in range(20):
        flat, m, v, loss = fn(flat, tgt, m, v, step, *batch,
                              jnp.float32(0.99))
        step = step + 1.0
        if loss_first is None:
            loss_first = float(loss)
    assert float(loss) < loss_first


def test_train_step_terminal_target_is_reward_only():
    """done=1 rows must regress Q(s,a) toward r irrespective of gamma."""
    flat, tgt = theta_for(), theta_for(1)
    feats_b, t_b, a_b, r_b, done_b = _batch(o=4)
    done_b = jnp.ones_like(done_b)
    l_g0 = dqn.td_loss(flat, tgt, feats_b, t_b, a_b, r_b, done_b,
                       jnp.float32(0.0), CFG)
    l_g9 = dqn.td_loss(flat, tgt, feats_b, t_b, a_b, r_b, done_b,
                       jnp.float32(0.99), CFG)
    np.testing.assert_allclose(l_g0, l_g9, rtol=1e-6)


def test_td_loss_zero_when_q_equals_target():
    """Sanity: loss is exactly the MSE of (target - Q)."""
    flat, tgt = theta_for(), theta_for()
    feats_b, t_b, a_b, r_b, done_b = _batch(o=4)
    rows = jnp.arange(4)
    q_on = jax.vmap(lambda f: dqn.qvalues_all(flat, f, CFG))(feats_b)
    t_next = jnp.minimum(t_b + 1, CFG.horizon - 1)
    a_star = jnp.argmax(q_on[rows, t_next], axis=-1)
    q_tg = jax.vmap(lambda f: dqn.qvalues_all(tgt, f, CFG))(feats_b)
    target = r_b + 0.5 * (1 - done_b) * q_tg[rows, t_next, a_star]
    want = jnp.mean((target - q_on[rows, t_b, a_b]) ** 2)
    got = dqn.td_loss(flat, tgt, feats_b, t_b, a_b, r_b, done_b,
                      jnp.float32(0.5), CFG)
    np.testing.assert_allclose(got, want, rtol=1e-5)
