"""L1 correctness: the Pallas fused matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including awkward non-block-aligned ones) and both
activations; explicit tests pin down gradients, padding edges and dtype.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import linear, matmul

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shapes(m, k, n, act, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    got = matmul(x, w, b, act)
    want = ref.matmul_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 64, 128),
                                   (8, 8, 8), (1, 1, 1), (33, 17, 9)])
def test_matmul_block_aligned_and_edges(m, k, n):
    x, w, b = rand(0, m, k), rand(1, k, n), rand(2, n)
    np.testing.assert_allclose(
        matmul(x, w, b, "none"), ref.matmul_ref(x, w, b, "none"),
        rtol=1e-4, atol=1e-4)


def test_matmul_no_bias():
    x, w = rand(0, 16, 32), rand(1, 32, 8)
    np.testing.assert_allclose(
        matmul(x, w, None, "none"), x @ w, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_activation():
    x, w = rand(0, 4, 4), rand(1, 4, 4)
    with pytest.raises(ValueError):
        matmul(x, w, None, "gelu")


def test_linear_grad_matches_ref_grad():
    x, w, b = rand(0, 24, 40), rand(1, 40, 12), rand(2, 12)

    def f_pl(x, w, b):
        return (linear(x, w, b, "relu") ** 2).sum()

    def f_ref(x, w, b):
        return (ref.matmul_ref(x, w, b, "relu") ** 2).sum()

    g_pl = jax.grad(f_pl, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for gp, gr in zip(g_pl, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-3)


def test_linear_grad_none_activation():
    x, w, b = rand(3, 9, 21), rand(4, 21, 5), rand(5, 5)
    g_pl = jax.grad(lambda w: linear(x, w, b, "none").sum())(w)
    g_ref = jax.grad(lambda w: ref.matmul_ref(x, w, b, "none").sum())(w)
    np.testing.assert_allclose(g_pl, g_ref, rtol=1e-3, atol=1e-3)


def test_linear_under_jit_scan_vmap():
    """The exact composition the AOT artifacts rely on."""
    x = rand(0, 8, 16)
    ws = jnp.stack([rand(i, 16, 16) * 0.1 for i in range(4)])
    b = jnp.zeros(16)

    def roll(w):
        def step(wc, _):
            y = linear(x, wc, b, "relu")
            g = jax.grad(lambda ww: linear(x, ww, b, "relu").mean())(wc)
            return wc - 0.1 * g, y.mean()

        wf, ys = jax.lax.scan(step, w, None, length=3)
        return ys

    got = jax.jit(jax.vmap(roll))(ws)
    assert got.shape == (4, 3)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_relu_grad_zero_where_inactive():
    x = jnp.array([[-5.0, 5.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2)
    g = jax.grad(lambda x: linear(x, w, b, "relu").sum())(x)
    np.testing.assert_allclose(g, [[0.0, 1.0]])
