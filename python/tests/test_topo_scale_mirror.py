"""Toolchain-less oracle for the scalable-topology substrate (PR 6).

Literal transcriptions of the PR 6 index/derivation math:

* ``rust/src/util/rng.rs``        — xoshiro256++ / SplitMix64 / Box–Muller
  (same port as ``test_dqn_train_mirror.py``, plus the cached-spare
  Gaussian the channel model consumes);
* ``rust/src/system/channel.rs``  — log-distance path loss + shadowing;
* ``rust/src/system/gains.rs``    — the lazy-gain determinism contract
  (``derive_gain`` link-seed mixing);
* ``rust/src/system/topology.rs`` — ``stream_seed`` decorrelation and the
  scalable per-device field draw order;
* ``rust/src/system/grid.rs``     — uniform-grid build, ring expansion,
  nearest / k-nearest with (distance, id) tie-breaks.

Integer pins (seed expansion, stream/link seeds, draw counts) are exact
across languages; float pins use 1e-9 relative tolerance (libm ulp).
The same constants are asserted from the Rust side in
``rust/tests/topo_scale.rs``, so a reordered draw or changed mixing
constant fails here without compiling any Rust.

Run: cd python && python3 -m pytest tests/test_topo_scale_mirror.py
"""
import math

MASK = (1 << 64) - 1


# ---------------- util/rng.rs transcription (xoshiro256++) ----------------

def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """rust/src/util/rng.rs, draw-for-draw (with the Gaussian spare)."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s, v = _splitmix64(s)
            self.s.append(v)
        self.gauss_spare = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo, hi):
        return lo + (hi - lo) * self.f64()

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def gaussian(self):
        if self.gauss_spare is not None:
            z, self.gauss_spare = self.gauss_spare, None
            return z
        u1 = 1.0 - self.f64()
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.gauss_spare = r * math.sin(theta)
        return r * math.cos(theta)

    def normal(self, mean, std):
        return mean + std * self.gaussian()


# ---------------- system/channel.rs transcription ----------------

PL_INTERCEPT_DB = 128.1
PL_SLOPE_DB = 37.6
SHADOW_STD_DB = 8.0


def mean_gain(dist_m, rng):
    d_km = max(dist_m / 1000.0, 1e-3)
    pl_db = PL_INTERCEPT_DB + PL_SLOPE_DB * math.log10(d_km) + rng.normal(0.0, SHADOW_STD_DB)
    return 10.0 ** (-pl_db / 10.0)


# ---------------- system/gains.rs + topology.rs seed mixing ----------------

def link_seed(device_seed, edge):
    return (device_seed ^ (((edge + 1) * 0xD6E8FEB86659FD93) & MASK)) & MASK


def derive_gain(device_seed, edge, dist_m):
    return mean_gain(dist_m, Rng(link_seed(device_seed, edge)))


def stream_seed(base, i):
    return (base + (((i + 1) * 0x9E3779B97F4A7C15) & MASK)) & MASK


# ---------------- system/grid.rs transcription ----------------

def _dist(a, b):
    return math.sqrt((a[0] - b[0]) ** 2 + (a[1] - b[1]) ** 2)


class SpatialGrid:
    def __init__(self, side, pts):
        assert pts and side > 0.0
        m = len(pts)
        cells = max(int(math.ceil(math.sqrt(m))), 1)
        self.cells = cells
        self.cell_size = side / cells
        n_cells = cells * cells
        counts = [0] * (n_cells + 1)
        for (x, y) in pts:
            counts[self._cell_index(x, y) + 1] += 1
        for c in range(1, n_cells + 1):
            counts[c] += counts[c - 1]
        self.starts = counts
        cursor = list(counts[:n_cells])
        self.items = [0] * m
        for pid, (x, y) in enumerate(pts):
            c = self._cell_index(x, y)
            self.items[cursor[c]] = pid
            cursor[c] += 1
        self.pts = list(pts)

    def _clamp_axis(self, v):
        # Rust: ((v / cell_size) as isize).clamp(0, cells-1) — `as isize`
        # truncates toward zero, which int() matches for our ranges
        return min(max(int(v / self.cell_size), 0), self.cells - 1)

    def _cell_index(self, x, y):
        return self._clamp_axis(y) * self.cells + self._clamp_axis(x)

    def _bucket(self, cx, cy):
        c = cy * self.cells + cx
        return self.items[self.starts[c]:self.starts[c + 1]]

    def _ring_cells(self, cx, cy, r):
        """In-bounds cells at Chebyshev distance exactly r, in the Rust
        visiting order. Returns (cells, any_in_bounds)."""
        if r == 0:
            return [(cx, cy)], True
        out = []
        for gx in range(cx - r, cx + r + 1):
            for gy in (cy - r, cy + r):
                if 0 <= gx < self.cells and 0 <= gy < self.cells:
                    out.append((gx, gy))
        for gy in range(cy - r + 1, cy + r):
            for gx in (cx - r, cx + r):
                if 0 <= gx < self.cells and 0 <= gy < self.cells:
                    out.append((gx, gy))
        return out, bool(out)

    def nearest(self, x, y):
        cx = self._clamp_axis(x)
        cy = self._clamp_axis(y)
        best_d = math.inf
        best = None
        r = 0
        while True:
            if best is not None:
                bound = max(r - 1.0, 0.0) * self.cell_size
                if bound > best_d:
                    break
            ring, any_cells = self._ring_cells(cx, cy, r)
            for (gx, gy) in ring:
                for pid in self._bucket(gx, gy):
                    d = _dist((x, y), self.pts[pid])
                    if d < best_d or (d == best_d and pid < best):
                        best_d = d
                        best = pid
            if not any_cells:
                break
            r += 1
        assert best is not None
        return best

    def k_nearest(self, x, y, k):
        if k == 0:
            return []
        cx = self._clamp_axis(x)
        cy = self._clamp_axis(y)
        out = []
        r = 0
        while True:
            if len(out) >= k:
                bound = max(r - 1.0, 0.0) * self.cell_size
                if bound > out[k - 1][0]:
                    break
            ring, any_cells = self._ring_cells(cx, cy, r)
            for (gx, gy) in ring:
                for pid in self._bucket(gx, gy):
                    out.append((_dist((x, y), self.pts[pid]), pid))
            if not any_cells:
                break
            out.sort(key=lambda t: (t[0], t[1]))
            del out[k:]
            r += 1
        return out


# ======================= tests =======================

def test_xoshiro_integer_pins():
    # exact cross-language integers, co-pinned in rust/tests/topo_scale.rs
    r = Rng(42)
    assert r.next_u64() == 15021278609987233951
    assert r.next_u64() == 5881210131331364753
    assert r.next_u64() == 18149643915985481100


def test_seed_mixing_integer_pins():
    # co-pinned in rust/tests/topo_scale.rs (seed_mixing_matches_python_mirror_pins)
    assert stream_seed(0x1234, 5) == 0xB54CDA58FBBEFAB2
    assert link_seed(42, 3) == 0x5BA3FAE19967F666
    assert link_seed(42, 3) == (42 ^ ((4 * 0xD6E8FEB86659FD93) & MASK)) & MASK


def test_derive_gain_order_independent_and_device_edge_distinct():
    fwd = [derive_gain(42, m, 500.0) for m in range(20)]
    bwd = [derive_gain(42, m, 500.0) for m in reversed(range(20))]
    assert fwd == bwd[::-1]
    assert all(g > 0.0 for g in fwd)
    assert derive_gain(1, 0, 500.0) != derive_gain(2, 0, 500.0)
    assert derive_gain(1, 0, 500.0) != derive_gain(1, 1, 500.0)


def test_mean_gain_path_loss_formula_without_shadowing():
    # 1 km, zero shadowing: gain = 10^-12.81 exactly (pinned in channel.rs)
    class Zero:
        def normal(self, mean, std):
            return 0.0

    g = mean_gain(1000.0, Zero())
    assert abs(math.log10(g) + 12.81) < 1e-9


def test_mean_gain_consumes_exactly_one_gaussian():
    # the determinism contract relies on one mean_gain call consuming one
    # shadow draw from a fresh stream; a cached-spare leak would break the
    # lazy == eager equivalence
    a, b = Rng(7), Rng(7)
    mean_gain(250.0, a)
    b.gaussian()
    assert a.next_u64() == b.next_u64()


def test_grid_nearest_matches_brute_force():
    rng = Rng(0x6121D)
    for m in (1, 2, 5, 17, 64, 300):
        side = 1000.0
        pts = [(rng.range(0.0, side), rng.range(0.0, side)) for _ in range(m)]
        g = SpatialGrid(side, pts)
        for _ in range(60):
            q = (rng.range(0.0, side), rng.range(0.0, side))
            brute = min(range(m), key=lambda i: (_dist(q, pts[i]), i))
            assert g.nearest(*q) == brute, f"m={m} q={q}"


def test_grid_k_nearest_matches_brute_force():
    rng = Rng(0x4EA7)
    for m in (3, 8, 50, 200):
        side = 1000.0
        pts = [(rng.range(0.0, side), rng.range(0.0, side)) for _ in range(m)]
        g = SpatialGrid(side, pts)
        for _ in range(40):
            q = (rng.range(0.0, side), rng.range(0.0, side))
            for k in (1, 4, 8):
                brute = sorted(
                    ((_dist(q, p), i) for i, p in enumerate(pts)),
                    key=lambda t: (t[0], t[1]),
                )[:k]
                assert g.k_nearest(*q, k) == brute, f"m={m} k={k} q={q}"


def test_grid_clustered_corner_queries():
    rng = Rng(7)
    side = 1000.0
    pts = [(rng.range(0.0, 50.0), rng.range(0.0, 50.0)) for _ in range(40)]
    g = SpatialGrid(side, pts)
    for q in ((999.0, 999.0), (0.0, 0.0), (500.0, 0.0), (0.0, 999.9)):
        brute = min(range(40), key=lambda i: (_dist(q, pts[i]), i))
        assert g.nearest(*q) == brute


def test_scalable_field_stream_draw_order():
    """topology.rs generate_scalable: per-device stream draws pos.x, pos.y,
    cycles, samples, tx — five uniform draws, order-independent across
    devices because each device gets its own stream_seed'd Rng."""
    side = 1000.0
    base = 0xBADDECAF
    for i in (0, 7, 123456):
        dr = Rng(stream_seed(base, i))
        pos = (dr.range(0.0, side), dr.range(0.0, side))
        cycles = dr.range(1e4, 1e5)
        samples = int(dr.range(300.0, 700.0))
        tx_dbm = dr.range(0.0, 23.0)
        assert 0.0 <= pos[0] <= side and 0.0 <= pos[1] <= side
        assert 1e4 <= cycles <= 1e5
        assert 300 <= samples <= 700
        assert 0.0 <= tx_dbm <= 23.0
        # re-deriving the same device consumes an identical stream
        dr2 = Rng(stream_seed(base, i))
        assert (dr2.range(0.0, side), dr2.range(0.0, side)) == pos


def test_float_pins_for_rust_co_pinning():
    """Values asserted (with 1e-9 rel tol) from rust/tests/topo_scale.rs —
    regenerate by running this test with -s if the contract changes."""
    g = derive_gain(42, 3, 500.0)
    assert abs(g - 5.955357191763563e-12) < 1e-9 * g, repr(g)
    gm = mean_gain(250.0, Rng(7))
    assert abs(gm - 2.122415362385412e-11) < 1e-9 * gm, repr(gm)
