"""Toolchain-less oracle for the exact branch-and-bound assigner (PR 8).

Literal stdlib-only transcription of ``rust/src/allocation/exact/mod.rs``:

* the admissible cheapest-marginal lower bound (each unassigned slot
  priced at its minimum marginal over candidate edges, marginals taken
  at the node's current per-edge masks);
* best-first frontier ordering with deterministic ``(bound, node_id)``
  tie-breaks (smaller bound first, then smaller id);
* the greedy constructive incumbent seed (strict ``<`` first-min
  tie-break);
* the full pop trace of the shared 3-slot / 2-edge supermodular table
  fixture, pinned bit-for-bit against the constants asserted by the Rust
  unit test ``exact::tests::mirror_trace_is_pinned``.

Every fixture value is a multiple of 0.25 (exactly representable in
binary floating point), so the cross-language pins use ``==`` — no
tolerance. A reordered pop, changed tie-break, or edited fixture fails
here without compiling any Rust.

Run: cd python && python3 -m pytest tests/test_exact_oracle_mirror.py
"""
import heapq
import math

INF = float("inf")


# ------------- allocation/exact/mod.rs::tests::TableCost -------------
#
# cost(m, mask) = w[m]*k + q[m]*k*(k-1)/2 + sum(a[s][m] for s in mask),
# k = popcount(mask). Supermodular for q >= 0: the marginal of adding a
# slot to a size-k group is w[m] + q[m]*k + a[s][m], non-decreasing in k.

class TableCost:
    def __init__(self, w, q, a, cands):
        self.w = w
        self.q = q
        self.a = a
        self.cands = cands

    @property
    def n_slots(self):
        return len(self.a)

    @property
    def n_edges(self):
        return len(self.w)

    def group_cost(self, m, mask):
        k = bin(mask).count("1")
        c = self.w[m] * k + self.q[m] * k * (k - 1) / 2
        s = 0
        bits = mask
        while bits:
            s = (bits & -bits).bit_length() - 1
            c += self.a[s][m]
            bits &= bits - 1
        return c


def mirror_fixture():
    """Keep in sync with exact::tests::mirror_fixture (mod.rs)."""
    return TableCost(
        w=[1.0, 1.0],
        q=[1.0, 0.0],
        a=[[0.0, 0.25], [0.0, 2.0], [0.0, 2.0]],
        cands=[[0, 1], [0, 1], [0, 1]],
    )


# ------------- greedy_seed (the incumbent constructor) -------------

def greedy_seed(t):
    masks = [0] * t.n_edges
    choices = []
    for s in range(t.n_slots):
        best_m, best_delta = None, INF
        for m in t.cands[s]:
            delta = t.group_cost(m, masks[m] | (1 << s)) - t.group_cost(m, masks[m])
            if delta < best_delta:  # strict <: first minimum wins ties
                best_delta, best_m = delta, m
        masks[best_m] |= 1 << s
        choices.append(best_m)
    total = sum(t.group_cost(m, masks[m]) for m in range(t.n_edges))
    return choices, total


# ------------- branch_and_bound_traced transcription -------------

def row_min(row):
    return min(row)


def branch_and_bound(t, node_budget=100_000):
    n, m_count = t.n_slots, t.n_edges
    if n == 0:
        return dict(choices=[], objective=0.0, lower_bound=0.0, proven=True,
                    nodes_expanded=0, trace=[])
    best_choices, best_obj = greedy_seed(t)

    # Root marginal matrix: rows = slots, non-candidate entries = inf.
    marg = [[INF] * m_count for _ in range(n)]
    for s in range(n):
        for m in t.cands[s]:
            marg[s][m] = t.group_cost(m, 1 << s) - t.group_cost(m, 0)
    root_bound = sum(row_min(r) for r in marg)

    SLACK = 1e-9
    heap = []
    next_id = 0
    # node tuple: (bound, id, depth, choices, masks, partial, marg)
    heapq.heappush(heap, (root_bound, next_id, 0, [], [0] * m_count, 0.0, marg))
    next_id += 1
    expanded = 0
    trace = []
    while heap:
        bound, nid, depth, choices, masks, partial, marg = heapq.heappop(heap)
        if bound >= best_obj - SLACK * abs(best_obj):
            break  # frontier min can't beat the incumbent: proven
        if expanded >= node_budget:
            return dict(choices=best_choices, objective=best_obj,
                        lower_bound=min(bound, best_obj), proven=False,
                        nodes_expanded=expanded, trace=trace)
        expanded += 1
        trace.append((nid, depth, bound))
        s = depth
        for e in t.cands[s]:
            delta = marg[0][e]
            child_partial = partial + delta
            child_depth = depth + 1
            if child_depth == n:
                obj = 0.0
                for m in range(m_count):
                    mask = masks[m] | ((1 << s) if m == e else 0)
                    obj += t.group_cost(m, mask)
                if obj < best_obj:
                    best_obj = obj
                    best_choices = choices + [e]
                continue
            rows = n - child_depth
            cmarg = [list(marg[r + 1]) for r in range(rows)]
            child_mask_e = masks[e] | (1 << s)
            base_e = t.group_cost(e, child_mask_e)
            for r in range(rows):
                slot = child_depth + r
                if e in t.cands[slot]:
                    cmarg[r][e] = t.group_cost(e, child_mask_e | (1 << slot)) - base_e
                else:
                    cmarg[r][e] = INF
            child_bound = child_partial + sum(row_min(r) for r in cmarg)
            if child_bound >= best_obj - SLACK * abs(best_obj):
                continue  # prune
            cmasks = list(masks)
            cmasks[e] = child_mask_e
            heapq.heappush(
                heap, (child_bound, next_id, child_depth, choices + [e],
                       cmasks, child_partial, cmarg))
            next_id += 1
    return dict(choices=best_choices, objective=best_obj, lower_bound=best_obj,
                proven=True, nodes_expanded=expanded, trace=trace)


def enumerate_best(t):
    """Exhaustive reference (mirrors bruteforce::enumerate_assignments)."""
    best_obj, best_choices = INF, None
    n, m_count = t.n_slots, t.n_edges

    def rec(s, masks, choices):
        nonlocal best_obj, best_choices
        if s == n:
            obj = sum(t.group_cost(m, masks[m]) for m in range(m_count))
            if obj < best_obj:
                best_obj, best_choices = obj, list(choices)
            return
        for e in t.cands[s]:
            masks[e] |= 1 << s
            choices.append(e)
            rec(s + 1, masks, choices)
            choices.pop()
            masks[e] &= ~(1 << s)

    rec(0, [0] * m_count, [])
    return best_choices, best_obj


# ----------------------------- pins -----------------------------

def test_lower_bound_is_admissible_on_fixture():
    """Root bound <= every complete assignment's objective."""
    t = mirror_fixture()
    marg = [[t.group_cost(m, 1 << s) if m in t.cands[s] else INF
             for m in range(t.n_edges)] for s in range(t.n_slots)]
    root_bound = sum(min(r) for r in marg)
    assert root_bound == 3.0  # min(1,1.25)+min(1,3)+min(1,3)
    _, best = enumerate_best(t)
    assert root_bound <= best


def test_greedy_seed_pins():
    t = mirror_fixture()
    choices, obj = greedy_seed(t)
    # Myopic pile-up on congested edge 0; slot 2 ties (delta 3.0 on both
    # edges) and the strict-< first-min keeps edge 0.
    assert choices == [0, 0, 0]
    assert obj == 6.0


def test_bnb_trace_pins():
    """The exact constants asserted by exact::tests::mirror_trace_is_pinned."""
    t = mirror_fixture()
    res = branch_and_bound(t)
    assert res["objective"] == 4.25
    assert res["choices"] == [1, 0, 0]
    assert res["proven"] is True
    assert res["lower_bound"] == 4.25
    assert res["trace"] == [(0, 0, 3.0), (2, 1, 3.25), (3, 2, 4.25)]
    assert res["nodes_expanded"] == 3


def test_bnb_matches_enumeration():
    t = mirror_fixture()
    res = branch_and_bound(t)
    choices, obj = enumerate_best(t)
    assert res["objective"] == obj
    assert res["choices"] == choices


def test_budget_degrades_to_greedy_incumbent():
    t = mirror_fixture()
    res = branch_and_bound(t, node_budget=1)
    assert res["proven"] is False
    assert res["choices"] == [0, 0, 0]  # greedy incumbent, still valid
    assert res["objective"] == 6.0
    assert res["lower_bound"] == 3.25  # smallest open bound at exhaustion
    assert res["lower_bound"] <= res["objective"]


def tie_fixture():
    """Fully symmetric 3-slot / 2-edge table: the root's two children tie
    at bound 3.0, so the pop order pins the (bound, node_id) rule. Keep
    in sync with exact::tests::equal_bound_ties_pop_in_id_order."""
    return TableCost(
        w=[1.0, 1.0],
        q=[1.0, 1.0],
        a=[[0.0, 0.0], [0.0, 0.0], [0.0, 0.0]],
        cands=[[0, 1], [0, 1], [0, 1]],
    )


def test_tie_breaks_prefer_lower_node_id():
    """Equal-bound frontier nodes pop in creation (id) order."""
    t = tie_fixture()
    res = branch_and_bound(t)
    # Greedy seeds [0, 1, 0] (slot 0 and the slot-2 tie both resolve to
    # edge 0 by strict <): F = cost0({0,2}) + cost1({1}) = 3 + 1 = 4.0,
    # which is optimal (any 2+1 split costs 4). The search still opens
    # the root's twin children (both bound 3.0) and must pop id 1 before
    # id 2; every grandchild bounds to 4.0 and prunes.
    assert res["objective"] == 4.0
    assert res["choices"] == [0, 1, 0]
    assert res["proven"] is True
    assert res["trace"] == [(0, 0, 3.0), (1, 1, 3.0), (2, 1, 3.0)]
    assert res["nodes_expanded"] == 3


def test_supermodular_marginals_never_decrease():
    """The admissibility precondition on the fixture: marginals of a slot
    on an edge are non-decreasing in the host group (mask inclusion)."""
    t = mirror_fixture()
    n, m_count = t.n_slots, t.n_edges
    for m in range(m_count):
        for s in range(n):
            for mask in range(1 << n):
                if mask & (1 << s):
                    continue
                for other in range(n):
                    bigger = mask | (1 << other)
                    if bigger == mask or bigger & (1 << s):
                        continue
                    small = t.group_cost(m, mask | (1 << s)) - t.group_cost(m, mask)
                    large = t.group_cost(m, bigger | (1 << s)) - t.group_cost(m, bigger)
                    assert large >= small
