"""L2 correctness: CNN / mini model built on the Pallas kernel vs lax ops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


SMALL = model.CnnConfig("small", in_ch=1, img=16, c1=4, c2=6, hidden=12)


def params_for(cfg, seed=0):
    return model.init_flat(jax.random.PRNGKey(seed), cfg.leaves())


def batch_for(cfg, n=4, seed=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, cfg.in_ch, cfg.img, cfg.img), jnp.float32)
    y = jax.nn.one_hot(
        jax.random.randint(ky, (n,), 0, model.NUM_CLASSES), model.NUM_CLASSES)
    return x, y


# ---------------------------------------------------------------------------
# building blocks vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4), c=st.integers(1, 3), img=st.integers(6, 14),
       oc=st.integers(1, 6), k=st.sampled_from([2, 3, 5]),
       seed=st.integers(0, 1000))
def test_conv2d_matches_lax(n, c, img, oc, k, seed):
    if img <= k:
        return
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (n, c, img, img), jnp.float32)
    w = jax.random.normal(k2, (oc, c, k, k), jnp.float32)
    b = jax.random.normal(k3, (oc,), jnp.float32)
    got = model.conv2d(x, w, b, "none")
    want = ref.conv2d_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_maxpool_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 9, 9), jnp.float32)
    np.testing.assert_allclose(model.maxpool2(x), ref.maxpool2_ref(x))


def test_softmax_xent_matches_ref():
    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 10), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(8) % 10, 10)
    np.testing.assert_allclose(
        model.softmax_xent(logits, y), ref.softmax_xent_ref(logits, y),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# parameter plumbing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [model.FMNIST, model.CIFAR, SMALL])
def test_flatten_unflatten_roundtrip(cfg):
    flat = params_for(cfg)
    p = model.unflatten(flat, cfg.leaves())
    flat2 = model.flatten(p, cfg.leaves())
    np.testing.assert_array_equal(flat, flat2)


def test_param_counts_match_paper_model_sizes():
    """Table I: z ≈ 448 KB (FashionMNIST), ≈ 882 KB (CIFAR-10)."""
    zf = 4 * model.param_count(model.FMNIST.leaves())
    zc = 4 * model.param_count(model.CIFAR.leaves())
    assert abs(zf - 448 * 1024) / (448 * 1024) < 0.05, zf
    assert abs(zc - 882 * 1024) / (882 * 1024) < 0.05, zc
    zm = 4 * model.param_count(model.MINI.leaves())
    assert abs(zm - 10 * 1024) / (10 * 1024) < 0.2, zm


def test_leaf_layout_offsets_contiguous():
    lay = model.leaf_layout(model.FMNIST.leaves())
    off = 0
    for leaf in lay:
        assert leaf["offset"] == off
        off += leaf["size"]
    assert off == model.param_count(model.FMNIST.leaves())


# ---------------------------------------------------------------------------
# forward / training behaviour
# ---------------------------------------------------------------------------


def test_cnn_forward_shape_and_finite():
    flat = params_for(SMALL)
    x, _ = batch_for(SMALL, n=3)
    logits = model.cnn_forward(flat, x, SMALL)
    assert logits.shape == (3, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_mini_forward_shape():
    flat = model.init_flat(jax.random.PRNGKey(0), model.MINI.leaves())
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 1, 10, 10), jnp.float32)
    assert model.mini_forward(flat, x).shape == (5, 10)


def test_local_round_reduces_loss():
    """5 SGD steps on a fixed batch must reduce the loss on that batch."""
    flat = params_for(SMALL)
    x, y = batch_for(SMALL, n=8)
    loss0 = model.cnn_loss(flat, x, y, SMALL)
    xs = jnp.stack([x] * 5)
    ys = jnp.stack([y] * 5)
    fn = model.make_local_round(SMALL)
    flat2, _ = jax.jit(fn)(flat, xs, ys, jnp.float32(0.05))
    loss1 = model.cnn_loss(flat2, x, y, SMALL)
    assert float(loss1) < float(loss0)


def test_local_round_batched_matches_single():
    db = 3
    fn_b = model.make_local_round_batched(SMALL, db)
    fn_s = model.make_local_round(SMALL)
    flats = jnp.stack([params_for(SMALL, seed=i) for i in range(db)])
    xs, ys = [], []
    for i in range(db):
        x, y = batch_for(SMALL, n=4, seed=10 + i)
        xs.append(jnp.stack([x] * 2))
        ys.append(jnp.stack([y] * 2))
    xs, ys = jnp.stack(xs), jnp.stack(ys)
    outb, lossb = jax.jit(fn_b)(flats, xs, ys, jnp.float32(0.01))
    for i in range(db):
        outs, losss = fn_s(flats[i], xs[i], ys[i], jnp.float32(0.01))
        np.testing.assert_allclose(outb[i], outs, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(lossb[i], losss, rtol=1e-4, atol=1e-5)


def test_init_flat_he_statistics():
    flat = model.init_flat(jax.random.PRNGKey(0), model.FMNIST.leaves())
    p = model.unflatten(flat, model.FMNIST.leaves())
    w = p["fc1_w"]
    std = float(w.std())
    expect = (2.0 / model.FMNIST.feat) ** 0.5
    assert abs(std - expect) / expect < 0.1
    assert float(jnp.abs(p["fc1_b"]).max()) == 0.0
