"""Toolchain-less oracle for the Rust blocked kernels (PR 2).

This is a literal, loop-for-loop Python transcription of
`rust/src/runtime/native/{gemm,ops}.rs` — same packing, same microtile
driver, same index formulas — validated against numpy einsum and finite
differences. When no Rust toolchain is available (see
.claude/skills/verify/SKILL.md), a change to the Rust kernel index math
should be mirrored here first: a bug in the tiling/im2col arithmetic
fails these tests without ever compiling the Rust.

Needs numpy only (no jax).
"""
import numpy as np
import pytest

MR, NR, KC = 4, 8, 256  # keep in sync with rust/src/runtime/native/gemm.rs


# ---------------- gemm.rs transcription ----------------

def pack_b(bsrc, k0, klen, j0, jlen, panel):
    kind, b, ldb = bsrc
    if kind == "row":
        for kk in range(klen):
            src = b[(k0 + kk) * ldb + j0:(k0 + kk) * ldb + j0 + jlen]
            panel[kk * NR:kk * NR + jlen] = src
            panel[kk * NR + jlen:kk * NR + NR] = 0.0
    else:  # transposed (NT)
        for kk in range(klen):
            for j in range(jlen):
                panel[kk * NR + j] = b[(j0 + j) * ldb + k0 + kk]
            panel[kk * NR + jlen:kk * NR + NR] = 0.0


def pack_a(asrc, i0, mr, k0, klen, apack):
    kind, a, lda = asrc
    if kind == "row":
        for r in range(mr):
            row = a[(i0 + r) * lda + k0:(i0 + r) * lda + k0 + klen]
            for kk, v in enumerate(row):
                apack[kk * MR + r] = v
    else:  # col-major (TN)
        for kk in range(klen):
            src = a[(k0 + kk) * lda + i0:(k0 + kk) * lda + i0 + mr]
            apack[kk * MR:kk * MR + mr] = src
    if mr < MR:
        for kk in range(klen):
            for r in range(mr, MR):
                apack[kk * MR + r] = 0.0


def microkernel(M, apack, panel, klen):
    acc = np.zeros((M, NR))
    for kk in range(klen):
        arow = apack[kk * MR:kk * MR + MR]
        brow = panel[kk * NR:kk * NR + NR]
        for r in range(M):
            acc[r] += arow[r] * brow
    return acc


def store_tile(M, acc, out, ldc, i0, j0, jlen, beta_one, apply_epi, epi):
    for r in range(M):
        base = (i0 + r) * ldc + j0
        for j in range(jlen):
            v = out[base + j] + acc[r][j] if beta_one else acc[r][j]
            if apply_epi and epi is not None:
                kind, bias, relu = epi
                if kind == "col":
                    v += bias[j0 + j]
                elif kind == "row":
                    v += bias[i0 + r]
                if relu and v < 0.0:
                    v = 0.0
            out[base + j] = v


def gemm_driver(asrc, bsrc, m, k, n, accumulate, epi, out):
    assert len(out) == m * n
    assert not accumulate or epi is None
    if m == 0 or n == 0:
        return
    if k == 0:
        # empty sum, but the epilogue still applies (matches gemm.rs)
        if not accumulate:
            for i in range(m):
                for j in range(n):
                    v = 0.0
                    if epi is not None:
                        kind, bias, relu = epi
                        if kind == "col":
                            v += bias[j]
                        elif kind == "row":
                            v += bias[i]
                        if relu and v < 0.0:
                            v = 0.0
                    out[i * n + j] = v
        return
    panel = np.zeros(KC * NR)
    apack = np.zeros(KC * MR)
    j0 = 0
    while j0 < n:
        jlen = min(NR, n - j0)
        k0 = 0
        while k0 < k:
            klen = min(KC, k - k0)
            pack_b(bsrc, k0, klen, j0, jlen, panel)
            beta_one = accumulate or k0 > 0
            apply_epi = k0 + klen == k
            i0 = 0
            while i0 < m:
                mr = min(MR, m - i0)
                pack_a(asrc, i0, mr, k0, klen, apack)
                acc = microkernel(mr, apack, panel, klen)
                store_tile(mr, acc, out, n, i0, j0, jlen, beta_one, apply_epi, epi)
                i0 += mr
            k0 += klen
        j0 += jlen


def gemm_nn(a, b, m, k, n, epi, out):
    gemm_driver(("row", a, k), ("row", b, n), m, k, n, False, epi, out)


def gemm_tn(a, b, k, m, n, accumulate, out):
    gemm_driver(("col", a, m), ("row", b, n), m, k, n, accumulate, None, out)


def gemm_nt(a, b, m, k, n, accumulate, out):
    gemm_driver(("row", a, k), ("trans", b, k), m, k, n, accumulate, None, out)


# ---------------- ops.rs transcription ----------------

def im2col(x, ic, ih, iw, k, col):
    oh, ow = ih - k + 1, iw - k + 1
    ohw = oh * ow
    for i in range(ic):
        xbase = i * ih * iw
        for ky in range(k):
            for kx in range(k):
                row = (i * k + ky) * k + kx
                cbase = row * ohw
                for yy in range(oh):
                    src = xbase + (yy + ky) * iw + kx
                    dst = cbase + yy * ow
                    col[dst:dst + ow] = x[src:src + ow]


def col2im(col, ic, ih, iw, k, dx):
    oh, ow = ih - k + 1, iw - k + 1
    ohw = oh * ow
    for i in range(ic):
        xbase = i * ih * iw
        for ky in range(k):
            for kx in range(k):
                row = (i * k + ky) * k + kx
                cbase = row * ohw
                for yy in range(oh):
                    dst = xbase + (yy + ky) * iw + kx
                    src = cbase + yy * ow
                    dx[dst:dst + ow] += col[src:src + ow]


def conv2d_fwd_cols(x, w, b, bsz, ic, ih, iw, oc, k, relu, cols, y):
    oh, ow = ih - k + 1, iw - k + 1
    kk, ohw = ic * k * k, oh * ow
    for bi in range(bsz):
        col = cols[bi * kk * ohw:(bi + 1) * kk * ohw]
        im2col(x[bi * ic * ih * iw:(bi + 1) * ic * ih * iw], ic, ih, iw, k, col)
        yb = y[bi * oc * ohw:(bi + 1) * oc * ohw]
        gemm_nn(w, col, oc, kk, ohw, ("row", b, relu), yb)


def conv2d_bwd_cols(cols, w, dy, bsz, ic, ih, iw, oc, k, dw, db, dx, dcol):
    oh, ow = ih - k + 1, iw - k + 1
    kk, ohw = ic * k * k, oh * ow
    dw[:] = 0.0
    db[:] = 0.0
    if dx is not None:
        dx[:] = 0.0
    for bi in range(bsz):
        dyb = dy[bi * oc * ohw:(bi + 1) * oc * ohw]
        for o in range(oc):
            db[o] += dyb[o * ohw:(o + 1) * ohw].sum()
        col = cols[bi * kk * ohw:(bi + 1) * kk * ohw]
        gemm_nt(dyb, col, oc, ohw, kk, True, dw)
        if dx is not None:
            gemm_tn(w, dyb, oc, kk, ohw, False, dcol)
            col2im(dcol, ic, ih, iw, k, dx[bi * ic * ih * iw:(bi + 1) * ic * ih * iw])


# ---------------- tests ----------------

GEMM_SHAPES = [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 17), (13, 300, 21),
               (8, 448, 220), (2, KC * 2 + 5, 11), (7, 13, 3)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
def test_gemm_variants_match_einsum(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    A = rng.standard_normal(m * k)
    B = rng.standard_normal(k * n)
    want = (A.reshape(m, k) @ B.reshape(k, n)).ravel()
    tol = 1e-9 * max(1.0, np.abs(want).max())

    out = np.zeros(m * n)
    gemm_nn(A, B, m, k, n, None, out)
    assert np.abs(out - want).max() <= tol

    At = A.reshape(m, k).T.copy().ravel()
    out2 = np.zeros(m * n)
    gemm_tn(At, B, k, m, n, False, out2)
    assert np.abs(out2 - want).max() <= tol

    Bt = B.reshape(k, n).T.copy().ravel()
    out3 = np.zeros(m * n)
    gemm_nt(A, Bt, m, k, n, False, out3)
    assert np.abs(out3 - want).max() <= tol

    out4 = want.copy()
    gemm_nt(A, Bt, m, k, n, True, out4)
    assert np.abs(out4 - 2 * want).max() <= 2 * tol


def test_empty_k_still_applies_epilogue():
    m, n = 3, 5
    bias = np.arange(n, dtype=float) - 2.0
    out = np.full(m * n, 7.0)
    gemm_nn(np.zeros(0), np.zeros(0), m, 0, n, ("col", bias, True), out)
    want = np.tile(np.maximum(bias, 0.0), m)
    assert np.allclose(out, want)
    plain = np.full(m * n, 7.0)
    gemm_nn(np.zeros(0), np.zeros(0), m, 0, n, None, plain)
    assert np.all(plain == 0.0)


def test_fused_epilogues():
    rng = np.random.default_rng(42)
    m, k, n = 5, 6, 13
    A = rng.standard_normal(m * k)
    B = rng.standard_normal(k * n)
    bias_c = rng.standard_normal(n)
    bias_r = rng.standard_normal(m)
    plain = A.reshape(m, k) @ B.reshape(k, n)
    out = np.zeros(m * n)
    gemm_nn(A, B, m, k, n, ("col", bias_c, True), out)
    assert np.allclose(out, np.maximum(plain + bias_c[None, :], 0.0).ravel())
    out = np.zeros(m * n)
    gemm_nn(A, B, m, k, n, ("row", bias_r, False), out)
    assert np.allclose(out, (plain + bias_r[:, None]).ravel())


CONV_SHAPES = [(1, 1, 5, 5, 1, 2), (3, 2, 7, 6, 5, 3), (5, 3, 9, 9, 4, 4),
               (8, 15, 12, 12, 28, 5), (2, 1, 10, 10, 4, 3)]


@pytest.mark.parametrize("bsz,ic,ih,iw,oc,k", CONV_SHAPES)
def test_conv_fwd_bwd_match_einsum(bsz, ic, ih, iw, oc, k):
    rng = np.random.default_rng(bsz * 100 + ic * 10 + k)
    oh, ow = ih - k + 1, iw - k + 1
    kkn, ohw = ic * k * k, oh * ow
    x = rng.standard_normal(bsz * ic * ih * iw)
    w = rng.standard_normal(oc * kkn)
    b = rng.standard_normal(oc)
    cols = np.zeros(bsz * kkn * ohw)
    y = np.zeros(bsz * oc * ohw)
    conv2d_fwd_cols(x, w, b, bsz, ic, ih, iw, oc, k, False, cols, y)
    X = x.reshape(bsz, ic, ih, iw)
    W = w.reshape(oc, ic, k, k)
    want = np.zeros((bsz, oc, oh, ow))
    for ky in range(k):
        for kx in range(k):
            want += np.einsum("bihw,oi->bohw", X[:, :, ky:ky + oh, kx:kx + ow], W[:, :, ky, kx])
    want += b[None, :, None, None]
    assert np.abs(y - want.ravel()).max() < 1e-9 * max(1.0, np.abs(want).max())

    dy = rng.standard_normal(bsz * oc * ohw)
    dw = np.zeros(oc * kkn)
    db = np.zeros(oc)
    dx = np.zeros(bsz * ic * ih * iw)
    dcol = np.zeros(kkn * ohw)
    conv2d_bwd_cols(cols, w, dy, bsz, ic, ih, iw, oc, k, dw, db, dx, dcol)
    DY = dy.reshape(bsz, oc, oh, ow)
    assert np.allclose(db, DY.sum(axis=(0, 2, 3)))
    want_dw = np.zeros((oc, ic, k, k))
    for ky in range(k):
        for kx in range(k):
            want_dw[:, :, ky, kx] = np.einsum("bohw,bihw->oi", DY, X[:, :, ky:ky + oh, kx:kx + ow])
    assert np.abs(dw - want_dw.ravel()).max() < 1e-9 * max(1.0, np.abs(want_dw).max())
    want_dx = np.zeros((bsz, ic, ih, iw))
    for ky in range(k):
        for kx in range(k):
            want_dx[:, :, ky:ky + oh, kx:kx + ow] += np.einsum("bohw,oi->bihw", DY, W[:, :, ky, kx])
    assert np.abs(dx - want_dx.ravel()).max() < 1e-9 * max(1.0, np.abs(want_dx).max())


def test_conv_bwd_dw_finite_differences():
    rng = np.random.default_rng(7)
    bsz, ic, ih, iw, oc, k = 3, 2, 6, 6, 3, 3  # bsz not a tile multiple
    oh = ow = ih - k + 1
    kkn, ohw = ic * k * k, oh * ow
    x = rng.standard_normal(bsz * ic * ih * iw)
    w = rng.standard_normal(oc * kkn) * 0.5
    b = rng.standard_normal(oc) * 0.1
    gvec = rng.standard_normal(bsz * oc * ohw)

    def loss_of(wv):
        cols = np.zeros(bsz * kkn * ohw)
        y = np.zeros(bsz * oc * ohw)
        conv2d_fwd_cols(x, wv, b, bsz, ic, ih, iw, oc, k, False, cols, y)
        return float((y * gvec).sum())

    cols = np.zeros(bsz * kkn * ohw)
    y = np.zeros(bsz * oc * ohw)
    conv2d_fwd_cols(x, w, b, bsz, ic, ih, iw, oc, k, False, cols, y)
    dw = np.zeros(oc * kkn)
    db = np.zeros(oc)
    dx = np.zeros_like(x)
    dcol = np.zeros(kkn * ohw)
    conv2d_bwd_cols(cols, w, gvec, bsz, ic, ih, iw, oc, k, dw, db, dx, dcol)
    eps = 1e-6
    for idx in [0, 7, len(w) // 2, len(w) - 1]:
        wp = w.copy()
        wp[idx] += eps
        wm = w.copy()
        wm[idx] -= eps
        fd = (loss_of(wp) - loss_of(wm)) / (2 * eps)
        assert abs(fd - dw[idx]) < 1e-4 * max(1.0, abs(fd))
