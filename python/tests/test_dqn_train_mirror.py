"""Toolchain-less oracle for the native D³QN training (PR 4).

This is a literal transcription of `rust/src/runtime/native/dqn.rs`
(cached BiLSTM forward, BPTT backward of the double-DQN TD loss) and
`rust/src/runtime/native/adam.rs` — same scan orders, same gate layout
`[i, f, g, o]`, same stop-gradient target, same f32 dtype — validated
against `python/compile/dqn.py` (`qvalues_all` forward semantics and
`jax.grad` of `td_loss`) and against finite differences. It also ports
the repo's xoshiro256++ `Rng` (`rust/src/util/rng.rs`) so the replay
pins and finite-difference harness in `rust/tests/{dqn_grad_parity,
drl_train_native}.rs` are co-pinned with the numbers asserted here: when
no Rust toolchain is available, a bug in the backward index math or a
reordered RNG draw fails these tests without compiling any Rust.

Run: cd python && python3 -m pytest tests/test_dqn_train_mirror.py
"""
import math

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from compile import dqn  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


# ---------------- util/rng.rs transcription (xoshiro256++) ----------------

MASK = (1 << 64) - 1


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """rust/src/util/rng.rs, draw-for-draw."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s, v = _splitmix64(s)
            self.s.append(v)

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def f32(self):
        return np.float32(self.f64())

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def range(self, lo, hi):
        return lo + (hi - lo) * self.f64()

    def glorot_uniform(self, n, fan_in, fan_out):
        lim = math.sqrt(6.0 / (fan_in + fan_out))
        return np.array([self.range(-lim, lim) for _ in range(n)], np.float32)


# ------------- runtime/native/dqn.rs + adam.rs transcription -------------


def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


class NativeDqnMirror:
    """Leaf layout + forward/backward of rust/src/runtime/native/dqn.rs."""

    def __init__(self, n_edges, hid, fc, dtype=np.float32):
        self.m = n_edges
        self.feat = n_edges + 3
        self.hid = hid
        self.fc = fc
        self.dtype = dtype
        f, h = self.feat, hid
        self.leaves = [
            ("lstm_wi", (f, 4 * h)),
            ("lstm_wh", (h, 4 * h)),
            ("lstm_b", (4 * h,)),
            ("fc_w", (2 * h, fc)),
            ("fc_b", (fc,)),
            ("v_w", (fc, 1)),
            ("v_b", (1,)),
            ("a_w", (fc, n_edges)),
            ("a_b", (n_edges,)),
        ]
        self.params = sum(int(np.prod(s)) for _, s in self.leaves)

    def unflat(self, theta):
        out, off = {}, 0
        for name, shape in self.leaves:
            size = int(np.prod(shape))
            out[name] = theta[off:off + size].reshape(shape)
            off += size
        return out

    def flat_grad(self, g):
        return np.concatenate([g[name].reshape(-1) for name, _ in self.leaves])

    def init_glorot(self, rng):
        """model::init_params(Init::GlorotUniform) draw-for-draw, incl. the
        OUTPUT_SCALE=0.1 on fc_w/v_w/a_w and zero biases."""
        out = np.zeros(self.params, np.float32)
        off = 0
        for name, shape in self.leaves:
            size = int(np.prod(shape))
            if not name.endswith("_b"):
                fan_in = shape[0] if len(shape) == 2 else size
                fan_out = shape[-1] if len(shape) == 2 else size
                v = rng.glorot_uniform(size, fan_in, fan_out)
                if name in ("fc2_w", "fc_w", "v_w", "a_w"):
                    v = (v * np.float32(0.1)).astype(np.float32)
                out[off:off + size] = v
            off += size
        return out

    def lstm_step(self, p, xw_t, h, c):
        hid = self.hid
        gates = (xw_t + h @ p["lstm_wh"]).astype(self.dtype)
        i = sigmoid(gates[:hid])
        f = sigmoid(gates[hid:2 * hid])
        g = np.tanh(gates[2 * hid:3 * hid])
        o = sigmoid(gates[3 * hid:])
        c2 = (f * c + i * g).astype(self.dtype)
        h2 = (o * np.tanh(c2)).astype(self.dtype)
        act = np.concatenate([i, f, g, o]).astype(self.dtype)
        return h2, c2, act

    def forward_cached(self, theta, feats):
        p = self.unflat(theta)
        hseq = feats.shape[0]
        hid = self.hid
        xw = (feats @ p["lstm_wi"] + p["lstm_b"]).astype(self.dtype)
        gates_f = np.zeros((hseq, 4 * hid), self.dtype)
        cs_f = np.zeros((hseq, hid), self.dtype)
        hs_f = np.zeros((hseq, hid), self.dtype)
        hh = np.zeros(hid, self.dtype)
        cc = np.zeros(hid, self.dtype)
        for t in range(hseq):
            hh, cc, gates_f[t] = self.lstm_step(p, xw[t], hh, cc)
            hs_f[t], cs_f[t] = hh, cc
        gates_b = np.zeros((hseq, 4 * hid), self.dtype)
        cs_b = np.zeros((hseq, hid), self.dtype)
        hs_b = np.zeros((hseq, hid), self.dtype)
        hh = np.zeros(hid, self.dtype)
        cc = np.zeros(hid, self.dtype)
        for t in reversed(range(hseq)):
            hh, cc, gates_b[t] = self.lstm_step(p, xw[t], hh, cc)
            hs_b[t], cs_b[t] = hh, cc
        hcat = np.concatenate([hs_f, hs_b], axis=1)
        trunks = np.maximum(hcat @ p["fc_w"] + p["fc_b"], 0.0).astype(self.dtype)
        adv = (trunks @ p["a_w"] + p["a_b"]).astype(self.dtype)
        v = (trunks @ p["v_w"] + p["v_b"]).astype(self.dtype)
        q = (v + adv - adv.mean(axis=1, keepdims=True, dtype=self.dtype)).astype(self.dtype)
        return dict(gates_f=gates_f, cs_f=cs_f, hs_f=hs_f, gates_b=gates_b,
                    cs_b=cs_b, hs_b=hs_b, hcat=hcat, trunks=trunks, q=q)

    def qvalues_all(self, theta, feats):
        return self.forward_cached(theta, feats)["q"]

    def backward_episode(self, theta, feats, cache, dq, g):
        """Accumulate dL/dθ of one episode into the dict `g` — the literal
        transcription of NativeDqn::backward_episode."""
        p = self.unflat(theta)
        hseq = feats.shape[0]
        hid, m = self.hid, self.m
        trunks, hcat = cache["trunks"], cache["hcat"]

        dv = dq.sum(axis=1, dtype=self.dtype)                  # (h,)
        da = (dq - dv[:, None] / m).astype(self.dtype)         # (h, m)

        g["a_w"] += trunks.T @ da
        g["a_b"] += da.sum(axis=0, dtype=self.dtype)
        g["v_b"] += dv.sum(dtype=self.dtype)
        g["v_w"] += (trunks.T @ dv)[:, None]

        dtrunk = (da @ p["a_w"].T + dv[:, None] * p["v_w"][:, 0]).astype(self.dtype)
        dtrunk[trunks <= 0.0] = 0.0

        g["fc_w"] += hcat.T @ dtrunk
        g["fc_b"] += dtrunk.sum(axis=0, dtype=self.dtype)
        dhcat = (dtrunk @ p["fc_w"].T).astype(self.dtype)

        wh = p["lstm_wh"]

        def cell_bwd(gates, c, c_prev, dh, dc):
            i, f, gg, o = (gates[:hid], gates[hid:2 * hid],
                           gates[2 * hid:3 * hid], gates[3 * hid:])
            tc = np.tanh(c)
            dcu = (dc + dh * o * (1.0 - tc * tc)).astype(self.dtype)
            dz = np.concatenate([
                dcu * gg * i * (1.0 - i),
                dcu * c_prev * f * (1.0 - f),
                dcu * i * (1.0 - gg * gg),
                dh * tc * o * (1.0 - o),
            ]).astype(self.dtype)
            return dz, (dcu * f).astype(self.dtype)

        # forward scan BPTT: anti-scan order t = h−1..0
        dz_f = np.zeros((hseq, 4 * hid), self.dtype)
        dh = np.zeros(hid, self.dtype)
        dc = np.zeros(hid, self.dtype)
        for t in reversed(range(hseq)):
            dh = (dh + dhcat[t, :hid]).astype(self.dtype)
            c_prev = cache["cs_f"][t - 1] if t > 0 else np.zeros(hid, self.dtype)
            dz_f[t], dc = cell_bwd(cache["gates_f"][t], cache["cs_f"][t], c_prev, dh, dc)
            dh = (wh @ dz_f[t]).astype(self.dtype)
        if hseq > 1:
            g["lstm_wh"] += cache["hs_f"][:hseq - 1].T @ dz_f[1:]

        # reverse scan BPTT: anti-scan order t = 0..h−1, prev state at t+1
        dz_b = np.zeros((hseq, 4 * hid), self.dtype)
        dh = np.zeros(hid, self.dtype)
        dc = np.zeros(hid, self.dtype)
        for t in range(hseq):
            dh = (dh + dhcat[t, hid:]).astype(self.dtype)
            c_prev = cache["cs_b"][t + 1] if t + 1 < hseq else np.zeros(hid, self.dtype)
            dz_b[t], dc = cell_bwd(cache["gates_b"][t], cache["cs_b"][t], c_prev, dh, dc)
            dh = (wh @ dz_b[t]).astype(self.dtype)
        if hseq > 1:
            g["lstm_wh"] += cache["hs_b"][1:].T @ dz_b[:hseq - 1]

        g["lstm_wi"] += feats.T @ (dz_f + dz_b)
        g["lstm_b"] += (dz_f + dz_b).sum(axis=0, dtype=self.dtype)

    def zero_grad(self):
        return {name: np.zeros(shape, self.dtype) for name, shape in self.leaves}

    def td_grad(self, theta, theta_tgt, feats_b, t_b, a_b, r_b, done_b, gamma):
        o, hseq = feats_b.shape[0], feats_b.shape[1]
        g = self.zero_grad()
        loss = 0.0
        for r in range(o):
            cache = self.forward_cached(theta, feats_b[r])
            q_tg = self.qvalues_all(theta_tgt, feats_b[r])
            t, a = int(t_b[r]), int(a_b[r])
            tn = min(t + 1, hseq - 1)
            a_star = int(np.argmax(cache["q"][tn]))
            target = self.dtype(r_b[r] + gamma * (1.0 - done_b[r]) * q_tg[tn, a_star])
            delta = self.dtype(target - cache["q"][t, a])
            loss += float(delta) ** 2
            dq = np.zeros((hseq, self.m), self.dtype)
            dq[t, a] = self.dtype(-2.0 * delta / o)
            self.backward_episode(theta, feats_b[r], cache, dq, g)
        return self.dtype(loss / o), self.flat_grad(g)

    def td_loss(self, theta, theta_tgt, feats_b, t_b, a_b, r_b, done_b, gamma):
        o, hseq = feats_b.shape[0], feats_b.shape[1]
        loss = 0.0
        for r in range(o):
            q_on = self.qvalues_all(theta, feats_b[r])
            q_tg = self.qvalues_all(theta_tgt, feats_b[r])
            t, a = int(t_b[r]), int(a_b[r])
            tn = min(t + 1, hseq - 1)
            a_star = int(np.argmax(q_on[tn]))
            target = r_b[r] + gamma * (1.0 - done_b[r]) * q_tg[tn, a_star]
            delta = float(target - q_on[t, a])
            loss += delta * delta
        return self.dtype(loss / o)


def adam_step(theta, grad, m, v, t, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8):
    """runtime/native/adam.rs in float32."""
    f32 = np.float32
    m2 = (f32(beta1) * m + f32(1.0 - beta1) * grad).astype(np.float32)
    v2 = (f32(beta2) * v + f32(1.0 - beta2) * grad * grad).astype(np.float32)
    bc1 = f32(1.0) - f32(beta1) ** f32(t)
    bc2 = f32(1.0) - f32(beta2) ** f32(t)
    theta2 = (theta - f32(lr) * (m2 / bc1) / (np.sqrt(v2 / bc2) + f32(eps))).astype(np.float32)
    return theta2, m2, v2


# ------------------------------ fixtures ------------------------------

CFG = dqn.DqnConfig(n_edges=3, horizon=7, hid=8, fc=8)


def mirror_for(cfg=CFG, dtype=np.float32):
    return NativeDqnMirror(cfg.n_edges, cfg.hid, cfg.fc, dtype)


def theta_np(seed, cfg=CFG):
    return np.asarray(dqn.init_flat(jax.random.PRNGKey(seed), cfg), np.float32)


def batch_for(seed, o, cfg=CFG):
    rng = np.random.RandomState(seed)
    feats = rng.rand(o, cfg.horizon, cfg.feat).astype(np.float32)
    t_b = rng.randint(0, cfg.horizon, size=o).astype(np.int32)
    a_b = rng.randint(0, cfg.n_edges, size=o).astype(np.int32)
    r_b = np.where(rng.rand(o) < 0.5, 1.0, -1.0).astype(np.float32)
    done_b = (t_b == cfg.horizon - 1).astype(np.float32)
    return feats, t_b, a_b, r_b, done_b


# ------------------------------- tests --------------------------------


def test_forward_matches_jax_qvalues_all():
    mir = mirror_for()
    theta = theta_np(0)
    feats = batch_for(1, 1)[0][0]
    q_mir = mir.qvalues_all(theta, feats)
    q_jax = np.asarray(dqn.qvalues_all(jnp.asarray(theta), jnp.asarray(feats), CFG))
    assert q_mir.shape == q_jax.shape == (CFG.horizon, CFG.n_edges)
    np.testing.assert_allclose(q_mir, q_jax, atol=2e-5, rtol=2e-5)


def test_forward_matches_jax_at_horizon_one():
    cfg1 = dqn.DqnConfig(n_edges=3, horizon=1, hid=8, fc=8)
    mir = mirror_for(cfg1)
    theta = theta_np(2, cfg1)
    feats = np.random.RandomState(3).rand(1, cfg1.feat).astype(np.float32)
    q_mir = mir.qvalues_all(theta, feats)
    q_jax = np.asarray(dqn.qvalues_all(jnp.asarray(theta), jnp.asarray(feats), cfg1))
    np.testing.assert_allclose(q_mir, q_jax, atol=2e-5, rtol=2e-5)


def test_backward_matches_jax_grad_of_td_loss():
    mir = mirror_for()
    theta, theta_tgt = theta_np(4), theta_np(5)
    feats, t_b, a_b, r_b, done_b = batch_for(6, 5)
    gamma = 0.95
    loss_j, grad_j = jax.value_and_grad(dqn.td_loss)(
        jnp.asarray(theta), jnp.asarray(theta_tgt), jnp.asarray(feats),
        jnp.asarray(t_b), jnp.asarray(a_b), jnp.asarray(r_b),
        jnp.asarray(done_b), gamma, CFG)
    loss_m, grad_m = mir.td_grad(theta, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
    assert abs(float(loss_j) - float(loss_m)) < 1e-5
    grad_j = np.asarray(grad_j)
    scale = max(1.0, float(np.abs(grad_j).max()))
    np.testing.assert_allclose(grad_m, grad_j, atol=1e-4 * scale, rtol=2e-3)


def test_backward_matches_float64_finite_differences():
    # the float64 mirror differentiated numerically pins the transcription
    # itself (independent of jax): central differences at eps=1e-6
    cfg = dqn.DqnConfig(n_edges=3, horizon=5, hid=4, fc=4)
    mir = mirror_for(cfg, np.float64)
    rng = np.random.RandomState(7)
    theta = rng.randn(mir.params).astype(np.float64) * 0.2
    theta_tgt = rng.randn(mir.params).astype(np.float64) * 0.2
    feats, t_b, a_b, r_b, done_b = batch_for(8, 3, cfg)
    feats = feats.astype(np.float64)
    gamma = 0.9
    _, grad = mir.td_grad(theta, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
    eps = 1e-6
    idx = rng.choice(mir.params, size=40, replace=False)
    for i in idx:
        tp = theta.copy(); tp[i] += eps
        tm = theta.copy(); tm[i] -= eps
        lp = mir.td_loss(tp, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
        lm = mir.td_loss(tm, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - grad[i]) < 1e-6 * max(1.0, abs(grad[i])), \
            f"param {i}: fd {fd} vs analytic {grad[i]}"


def test_adam_matches_python_reference_formulas():
    # the adam.rs arithmetic against the make_train_step formulas (jnp) on
    # identical inputs, over several steps
    rng = np.random.RandomState(9)
    n = 64
    theta = rng.randn(n).astype(np.float32)
    m = np.zeros(n, np.float32)
    v = np.zeros(n, np.float32)
    jm, jv, jt = jnp.zeros(n), jnp.zeros(n), jnp.asarray(theta)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8
    for t in range(1, 6):
        g = rng.randn(n).astype(np.float32)
        theta, m, v = adam_step(theta, g, m, v, t, lr, b1, b2, eps)
        gj = jnp.asarray(g)
        jm = b1 * jm + (1.0 - b1) * gj
        jv = b2 * jv + (1.0 - b2) * gj * gj
        mhat = jm / (1.0 - b1 ** jnp.float32(t))
        vhat = jv / (1.0 - b2 ** jnp.float32(t))
        jt = jt - lr * mhat / (jnp.sqrt(vhat) + eps)
        np.testing.assert_allclose(theta, np.asarray(jt), atol=1e-6, rtol=1e-5)


def test_full_train_step_tracks_jax_make_train_step_loss():
    # end-to-end: one mirror train step vs the lowered-artifact semantics;
    # losses must agree tightly (θ' only loosely — Adam normalizes tiny
    # gradient components to ±lr, amplifying f32 noise on them)
    mir = mirror_for()
    theta, theta_tgt = theta_np(10), theta_np(11)
    feats, t_b, a_b, r_b, done_b = batch_for(12, 6)
    gamma = 0.99
    step_fn = dqn.make_train_step(CFG)
    flat2, m2, v2, loss_j = step_fn(
        jnp.asarray(theta), jnp.asarray(theta_tgt), jnp.zeros(mir.params),
        jnp.zeros(mir.params), jnp.float32(0.0), jnp.asarray(feats),
        jnp.asarray(t_b), jnp.asarray(a_b), jnp.asarray(r_b),
        jnp.asarray(done_b), gamma)
    loss_m, grad_m = mir.td_grad(theta, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
    theta2, _, _ = adam_step(theta, grad_m,
                             np.zeros(mir.params, np.float32),
                             np.zeros(mir.params, np.float32), 1)
    assert abs(float(loss_j) - float(loss_m)) < 1e-5
    # update magnitudes are capped by lr on both paths
    assert np.abs(theta2 - theta).max() <= 1e-3 + 1e-6
    assert np.abs(np.asarray(flat2) - theta).max() <= 1e-3 + 1e-6
    # where the gradient is clearly nonzero the update direction agrees
    gj = np.asarray(jax.grad(dqn.td_loss)(
        jnp.asarray(theta), jnp.asarray(theta_tgt), jnp.asarray(feats),
        jnp.asarray(t_b), jnp.asarray(a_b), jnp.asarray(r_b),
        jnp.asarray(done_b), gamma, CFG))
    strong = np.abs(gj) > 1e-4
    assert strong.any()
    np.testing.assert_allclose(theta2[strong], np.asarray(flat2)[strong],
                               atol=2e-4, rtol=0)


# ------------- co-pins with the Rust finite-difference tests -------------


def test_fd_harness_replica_at_f32_passes_rust_tolerances():
    """Replicates rust/tests/dqn_grad_parity.rs bit-for-bit on the data
    side (xoshiro draws, glorot init) and runs the same central-difference
    check in float32 with the same eps/tolerance the Rust test uses. If
    this holds with margin here, it holds there (the only difference is
    GEMM accumulation order, ~1e-6).

    gamma is 0 on purpose: for gamma>0 the double-DQN target jumps when a
    perturbation flips the argmax — the analytic gradient is correctly 0
    for that piecewise-constant term (stop-gradient), but finite
    differences across the tie see the jump. gamma=0 keeps the probe loss
    piecewise-smooth while the gradient still flows through q_sa into all
    nine leaves; the gamma>0 path is covered by the jax.grad parity test
    above. eps=5e-4 stays below the nearest trunk-ReLU boundary of these
    pinned seeds (measured gap 1.5e-3 / 6.1e-5 for h=5/9)."""
    for h, seed in ((5, 0xF0D5), (9, 0xF0D9)):
        mir = NativeDqnMirror(3, 4, 4)
        rng = Rng(seed)
        theta = mir.init_glorot(rng)
        theta_tgt = mir.init_glorot(rng)
        o = 4
        feats = np.array([rng.f32() for _ in range(o * h * mir.feat)],
                         np.float32).reshape(o, h, mir.feat)
        t_b = np.array([rng.below(h) for _ in range(o)], np.int32)
        a_b = np.array([rng.below(mir.m) for _ in range(o)], np.int32)
        r_b = np.array([1.0 if rng.f64() < 0.5 else -1.0 for _ in range(o)], np.float32)
        done_b = (t_b == h - 1).astype(np.float32)
        gamma = np.float32(0.0)
        _, grad = mir.td_grad(theta, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
        eps = np.float32(5e-4)
        worst = 0.0
        for i in range(mir.params):
            tp = theta.copy(); tp[i] += eps
            tm = theta.copy(); tm[i] -= eps
            lp = mir.td_loss(tp, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
            lm = mir.td_loss(tm, theta_tgt, feats, t_b, a_b, r_b, done_b, gamma)
            fd = (float(lp) - float(lm)) / (2.0 * float(eps))
            tol = 1e-3 * max(1.0, abs(float(grad[i])), abs(fd))
            err = abs(fd - float(grad[i]))
            worst = max(worst, err / tol)
            assert err <= tol, f"h={h} param {i}: fd {fd} vs analytic {grad[i]}"
        # demand real margin so the Rust run (slightly different float
        # accumulation order) cannot sit on the edge
        assert worst < 0.5, f"h={h}: FD margin too thin ({worst:.3f} of tolerance)"


def test_xoshiro_port_matches_rust_pins():
    """The draw sequence hardcoded in rust/tests/drl_train_native.rs
    (replay sampling pinned under the cell RNG stream). Keep both lists
    identical."""
    rng = Rng(0xC311)
    draws = [rng.below(4) for _ in range(8)]
    assert draws == XOSHIRO_BELOW4_PINS, draws


# Generated by this file's Rng port; asserted verbatim by the Rust test.
XOSHIRO_BELOW4_PINS = [2, 2, 1, 1, 3, 1, 1, 1]
