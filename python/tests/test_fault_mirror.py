"""Toolchain-less oracle for the fault-injection layer (ISSUE 7).

Literal transcriptions of the ``rust/src/faults/mod.rs`` derivation math:

* ``rust/src/util/rng.rs``   — xoshiro256++ / SplitMix64 / Box–Muller (the
  same port as ``test_topo_scale_mirror.py``);
* the per-draw seed mixing ``plan_seed ^ kind·KIND_MUL ^ (round+1)·ROUND_MUL
  ^ (id+1)·ID_MUL`` that makes every fault draw a pure function of
  ``(seed, round, kind, id)`` — the determinism contract behind the
  byte-identical lossy traces;
* the straggler tail ``1 + exp(N(μ, σ))`` (first uniform gates, then one
  Gaussian shapes the tail);
* the retry backoff schedule ``min(base · 2^(streak-1), cap)``.

Float pins here are asserted (at coarser tolerance) from the Rust side in
``rust/src/faults/mod.rs`` (``draws_match_python_mirror``), so a reordered
draw or changed mixing constant fails in CI without compiling any Rust.

Run: cd python && python3 -m pytest tests/test_fault_mirror.py
"""
import math

MASK = (1 << 64) - 1


# ---------------- util/rng.rs transcription (xoshiro256++) ----------------

def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """rust/src/util/rng.rs, draw-for-draw."""

    def __init__(self, seed):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s, v = _splitmix64(s)
            self.s.append(v)
        self.gauss_spare = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gaussian(self):
        if self.gauss_spare is not None:
            z, self.gauss_spare = self.gauss_spare, None
            return z
        u1 = 1.0 - self.f64()
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.gauss_spare = r * math.sin(theta)
        return r * math.cos(theta)


# ---------------- faults/mod.rs seed mixing ----------------

STRAGGLER = 0x57A6
DROPOUT = 0xD801
OUTAGE = 0x007A
CHURN = 0xC402

KIND_MUL = 0xE7037ED1A0B428DB
ROUND_MUL = 0x9E3779B97F4A7C15
ID_MUL = 0xA0761D6478BD642F

FAULT_SEED_TAG = 0xFA17


def draw_seed(plan_seed, round_i, kind, dev):
    return (
        plan_seed
        ^ ((kind * KIND_MUL) & MASK)
        ^ (((round_i + 1) * ROUND_MUL) & MASK)
        ^ (((dev + 1) * ID_MUL) & MASK)
    ) & MASK


def uniform(plan_seed, round_i, kind, dev):
    """The gating uniform of one fault draw (dropout/churn/outage compare
    this against their probability)."""
    return Rng(draw_seed(plan_seed, round_i, kind, dev)).f64()


def straggler_mult(plan_seed, round_i, dev, prob, mu, sigma):
    if prob == 0.0:
        return 1.0
    rng = Rng(draw_seed(plan_seed, round_i, STRAGGLER, dev))
    if rng.f64() < prob:
        return 1.0 + math.exp(mu + sigma * rng.gaussian())
    return 1.0


def backoff_delays(base, cap, misses):
    """Rounds a device stays blocked after its k-th consecutive miss.

    Only *device-fault* causes (dropout, deadline) feed this schedule:
    ``resolve`` exempts ``FailCause::Outage`` — an edge outage is the
    infrastructure's fault, so its victims keep their streak and are
    rescheduled immediately (ISSUE 9 satellite).
    """
    out = []
    for k in range(1, misses + 1):
        out.append(max(min(base << min(k - 1, 16), cap), 1))
    return out


def staleness_weight(alpha, staleness):
    """rust/src/faults/stale.rs AsyncCfg::weight: a buffered update that is
    ``s`` rounds old is mixed into eq. 2 at ``alpha**s`` of its fresh mass."""
    return alpha ** staleness


# ======================= tests =======================

def test_plan_seed_derivation():
    # FaultPlan::for_deployment — co-pinned in rust/src/scenario/spec.rs
    # (toml_fault_profile_and_overrides)
    assert 42 ^ FAULT_SEED_TAG == 64061
    # distinct kinds / rounds / devices decorrelate the streams
    base = draw_seed(7, 3, STRAGGLER, 5)
    assert base != draw_seed(7, 3, DROPOUT, 5)
    assert base != draw_seed(7, 4, STRAGGLER, 5)
    assert base != draw_seed(7, 3, STRAGGLER, 6)


def test_straggler_tail_pin():
    # co-pinned in rust/src/faults/mod.rs (draws_match_python_mirror):
    # seed 7, round 3, device 5, μ = σ = 0.5, prob 1.0
    m = straggler_mult(7, 3, 5, 1.0, 0.5, 0.5)
    assert abs(m - 3.4141072310631544) < 1e-12, repr(m)
    # the tail multiplies ON TOP of the nominal time: never below 1
    for dev in range(50):
        assert straggler_mult(7, 0, dev, 1.0, 0.5, 0.5) > 1.0
    # prob 0 short-circuits without consuming any stream
    assert straggler_mult(7, 3, 5, 0.0, 9.9, 9.9) == 1.0


def test_gating_uniform_pins():
    # the uniforms the Rust unit test brackets with 0.068 / 0.24 / 0.292
    u = uniform(7, 0, DROPOUT, 0)
    assert abs(u - 0.06756520095316365) < 1e-12, repr(u)
    u = uniform(7, 0, CHURN, 0)
    assert abs(u - 0.24274335941335856) < 1e-12, repr(u)
    u = uniform(7, 2, OUTAGE, 1)
    assert abs(u - 0.2910004507266095) < 1e-12, repr(u)


def test_per_device_dropout_stream_pins():
    # dropout u(7, 4, n) for n = 0..5 — rust asserts device 4 (< 0.5) drops
    # while device 0 (> 0.5) lands in draws_are_stateless_and_order_free
    us = [uniform(7, 4, DROPOUT, n) for n in range(6)]
    want = [0.7177, 0.9830, 0.9321, 0.7135, 0.4529, 0.8103]
    for u, w in zip(us, want):
        assert abs(u - w) < 5e-5, (us, want)
    assert us[4] < 0.5 < us[0]


def test_churn_stream_pins():
    # churn u(7, 0, n) for n = 0..3 — device 0 churns at churn_prob ≈ 0.243
    # (filter_drops_churned_devices_without_penalty)
    us = [uniform(7, 0, CHURN, n) for n in range(4)]
    want = [0.2427, 0.1585, 0.5738, 0.9471]
    for u, w in zip(us, want):
        assert abs(u - w) < 5e-5, (us, want)


def test_draws_are_stateless_and_order_free():
    fwd = [uniform(7, 1, DROPOUT, n) for n in range(20)]
    bwd = [uniform(7, 1, DROPOUT, n) for n in reversed(range(20))]
    assert fwd == bwd[::-1]
    assert all(0.0 <= u < 1.0 for u in fwd)
    # re-drawing consumes an identical fresh stream every time
    assert uniform(7, 1, DROPOUT, 3) == fwd[3]


def test_backoff_schedule_pins():
    # co-pinned in rust/src/faults/mod.rs (backoff_doubles_and_caps)
    assert backoff_delays(1, 8, 6) == [1, 2, 4, 8, 8, 8]
    assert backoff_delays(2, 16, 6) == [2, 4, 8, 16, 16, 16]
    # base ≥ 1 invariant: the delay never collapses to zero
    assert backoff_delays(1, 1, 3) == [1, 1, 1]
    # the shift is clamped at 16 so huge streaks cannot overflow
    assert backoff_delays(1, 1 << 40, 70)[-1] == 1 << 16


def test_staleness_weight_schedule():
    # co-pinned in rust/src/faults/stale.rs (weight_schedule_matches_python_mirror)
    want = [1.0, 0.5, 0.25, 0.125, 0.0625]
    for s, w in enumerate(want):
        assert abs(staleness_weight(0.5, s) - w) < 1e-15, (s, w)
    assert abs(staleness_weight(0.7, 3) - 0.343) < 1e-12
    # staleness 0 is full weight (the entry is kept, not consumed, that
    # round); past max_staleness the buffer evicts, so no weight applies
    assert staleness_weight(0.9, 0) == 1.0
    # alpha = 0 disables the async path entirely (gate, not a weight)
    assert staleness_weight(0.0, 1) == 0.0
