"""Pure-jnp oracle for the L1 Pallas kernel and the L2 model blocks.

Everything here is deliberately the most boring possible jnp implementation;
pytest asserts the Pallas kernel (and the model built on it) matches these
within float tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w, b=None, act: str = "none"):
    out = x @ w
    if b is not None:
        out = out + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(act)
    return out


def conv2d_ref(x, w, b):
    """Valid 2-D convolution, NCHW x OIHW -> NCHW, via lax.conv."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def maxpool2_ref(x):
    """2x2 max pool, NCHW, floor semantics."""
    n, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, : h2 * 2, : w2 * 2]
    x = x.reshape(n, c, h2, 2, w2, 2)
    return x.max(axis=(3, 5))


def softmax_xent_ref(logits, y_onehot):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(y_onehot * logp).sum(axis=-1).mean()


def lstm_cell_ref(x, h, c, wi, wh, b):
    """Standard LSTM cell; gate order [i, f, g, o]."""
    gates = x @ wi + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    return h2, c2
