"""L1 — Pallas fused matmul(+bias+activation) kernel.

This is the compute hot-spot of the HFL reproduction: every dense layer and
every convolution (via im2col) in the L2 jax models flows through this
kernel, in both the forward and backward pass (the backward pass is two more
invocations of the same kernel via a custom VJP).

TPU-idiomatic structure (see DESIGN.md §Hardware-Adaptation):

* 3-D grid ``(M/bm, N/bn, K/bk)`` — the K axis is the innermost, sequential
  ("arbitrary") dimension so the (bm, bn) accumulator tile stays resident in
  VMEM across K steps.
* MXU-aligned default tiles of 128×128×128, shrunk per call so tiny layers
  (e.g. the 25-row im2col K of a 5×5 conv) do not pad to absurdity.
* fp32 accumulate (``preferred_element_type``), bias add + activation fused
  into the final K step so the tile is written to HBM exactly once.

``interpret=True`` is mandatory on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode lowers
the kernel to plain HLO while preserving the block structure, so the
artifact runs anywhere; real-TPU performance is *estimated* from the block
shapes in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile for real-TPU compilation. One 128x128 fp32 accumulator
# tile (64 KiB) + two input tiles (64 KiB each) in VMEM — ~192 KiB/core,
# far below the ~16 MiB budget, leaving room for double-buffering the
# HBM->VMEM pipeline.
TPU_BLOCK = 128

# CPU-interpret tile: grid iterations lower to sequential dynamic-slice
# loops that XLA:CPU cannot fuse or vectorize across (measured 10-30x
# slowdown vs a single fused dot). On CPU we therefore tile only matrices
# that exceed this edge, so almost every layer runs as one grid cell =
# one fused XLA dot. The BlockSpec schedule is identical code — only the
# tile size changes per backend (DESIGN.md §Perf / §Hardware-Adaptation).
CPU_BLOCK = 2048

DEFAULT_BLOCK = CPU_BLOCK

_ACTIVATIONS = ("none", "relu")


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, preferred: int) -> int:
    """Whole (8-aligned) dim if it fits in `preferred`, else `preferred`."""
    if dim <= preferred:
        return _ceil_to(dim, 8)
    return preferred


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One (bm, bn) output tile; K accumulated across grid axis 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        r = o_ref[...] + b_ref[...][None, :]
        if act == "relu":
            r = jnp.maximum(r, 0.0)
        o_ref[...] = r


def matmul_padded(x, w, b, act: str, bm: int, bn: int, bk: int):
    """Pallas call on block-aligned operands. Shapes must divide evenly."""
    m, k = x.shape
    _, n = w.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2], act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def matmul(x, w, b=None, act: str = "none", block: int = DEFAULT_BLOCK):
    """act(x @ w + b) through the Pallas kernel, with automatic padding.

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32 or None.
    """
    if act not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((n,), jnp.float32)

    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    bk = _pick_block(k, block)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b

    out = matmul_padded(xp, wp, bp, act, bm, bn, bk)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


# ---------------------------------------------------------------------------
# Differentiable fused linear layer: forward AND backward run on the kernel.
# relu gradient is recovered from the saved post-activation output
# (out > 0 <=> pre-activation > 0), so no pre-activation tensor is kept.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def linear(x, w, b, act: str = "none", block: int = DEFAULT_BLOCK):
    """Differentiable act(x @ w + b); both passes on the Pallas kernel."""
    return matmul(x, w, b, act, block)


def _linear_fwd(x, w, b, act, block):
    out = matmul(x, w, b, act, block)
    return out, (x, w, out)


def _linear_bwd(act, block, res, g):
    x, w, out = res
    if act == "relu":
        g = g * (out > 0).astype(g.dtype)
    dx = matmul(g, w.T, None, "none", block)
    dw = matmul(x.T, g, None, "none", block)
    db = g.sum(axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
