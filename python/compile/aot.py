"""AOT compile path: lower every L2/L1 computation to HLO text + manifest.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
≥0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/load_hlo/).

Emitted artifacts (all f32 unless noted):

| name                  | signature |
|-----------------------|-----------|
| local_round_<ds>      | (params[DB,P], xs[DB,L,B,C,H,W], ys[DB,L,B,10], lr) -> (params'[DB,P], loss[DB]) |
| eval_<ds>             | (params[P], x[EB,C,H,W]) -> logits[EB,10] |
| mini_local_round      | (params[DB,Pm], xs[DB,L,B,1,10,10], ys[DB,L,B,10], lr) -> (params'[DB,Pm], loss[DB]) |
| dqn_q_all_h<H>        | (theta[Pq], feats[H,F]) -> q[H,M] |
| dqn_train             | (theta, theta_tgt, m, v, step, feats[O,H,F], t[O]i32, a[O]i32, r[O], done[O], gamma) -> (theta', m', v', loss) |

plus `manifest.json` describing parameter layouts, shapes and constants so
the Rust coordinator is fully self-describing at runtime.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import dqn, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_one(fn, specs, path: str, verbose: bool = True) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    if verbose:
        print(f"  wrote {os.path.basename(path):32s} "
              f"{len(text) / 1e6:7.2f} MB  ({time.time() - t0:5.1f}s)")
    return {
        "file": os.path.basename(path),
        "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                   for s in specs],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--local-batch", type=int, default=8,
                    help="B: minibatch per local SGD step (paper: full batch;"
                         " see DESIGN.md §5)")
    ap.add_argument("--local-iters", type=int, default=5, help="L (Table I)")
    ap.add_argument("--device-slots", type=int, default=8,
                    help="DB: vmapped device slots per local_round call")
    ap.add_argument("--eval-batch", type=int, default=250)
    ap.add_argument("--dqn-hid", type=int, default=32,
                    help="LSTM hidden (paper: 256; default shrunk for CPU"
                         " wall-clock, see DESIGN.md §5)")
    ap.add_argument("--dqn-fc", type=int, default=32)
    ap.add_argument("--dqn-batch", type=int, default=64,
                    help="O: replay minibatch (paper: 128)")
    ap.add_argument("--dqn-lr", type=float, default=1e-3)
    ap.add_argument("--n-edges", type=int, default=5, help="M (Table I)")
    ap.add_argument("--horizons", type=int, nargs="+",
                    default=[10, 30, 50, 100],
                    help="H values for which q_all inference is lowered")
    ap.add_argument("--train-horizon", type=int, default=50,
                    help="H used by Algorithm 5 (paper: 50)")
    ap.add_argument("--skip-cifar", action="store_true")
    args = ap.parse_args()

    out = args.out
    os.makedirs(out, exist_ok=True)
    db, L, B, eb = (args.device_slots, args.local_iters, args.local_batch,
                    args.eval_batch)

    manifest = {
        "consts": {
            "db": db, "l": L, "b": B, "eb": eb,
            "n_edges": args.n_edges,
            "feat": args.n_edges + 3,
            "o": args.dqn_batch,
            "dqn_hid": args.dqn_hid,
            "dqn_fc": args.dqn_fc,
            "dqn_lr": args.dqn_lr,
            "train_horizon": args.train_horizon,
            "horizons": args.horizons,
            "num_classes": model.NUM_CLASSES,
        },
        "models": {},
        "artifacts": {},
    }

    datasets = [model.FMNIST] if args.skip_cifar else [model.FMNIST,
                                                       model.CIFAR]

    # --- CNN local rounds + eval -----------------------------------------
    for cfg in datasets:
        leaves = cfg.leaves()
        p = model.param_count(leaves)
        manifest["models"][cfg.name] = {
            "params": p,
            "leaves": model.leaf_layout(leaves),
            "img": cfg.img, "in_ch": cfg.in_ch,
            "bytes": 4 * p,
        }
        print(f"[{cfg.name}] params={p} ({4 * p / 1024:.0f} KB)")

        lr_fn = model.make_local_round_batched(cfg, db)
        specs = [
            spec((db, p)),
            spec((db, L, B, cfg.in_ch, cfg.img, cfg.img)),
            spec((db, L, B, model.NUM_CLASSES)),
            spec(()),
        ]
        manifest["artifacts"][f"local_round_{cfg.name}"] = lower_one(
            lr_fn, specs, os.path.join(out, f"local_round_{cfg.name}.hlo.txt"))

        ev_fn = model.make_eval(cfg)
        specs = [spec((p,)), spec((eb, cfg.in_ch, cfg.img, cfg.img))]
        manifest["artifacts"][f"eval_{cfg.name}"] = lower_one(
            ev_fn, specs, os.path.join(out, f"eval_{cfg.name}.hlo.txt"))

    # --- mini model (IKC clustering) --------------------------------------
    mini_leaves = model.MINI.leaves()
    pm = model.param_count(mini_leaves)
    manifest["models"]["mini"] = {
        "params": pm,
        "leaves": model.leaf_layout(mini_leaves),
        "img": model.MINI.img, "in_ch": model.MINI.in_ch,
        "bytes": 4 * pm,
    }
    print(f"[mini] params={pm} ({4 * pm / 1024:.1f} KB)")
    mini_fn = model.make_mini_local_round_batched(db)
    specs = [
        spec((db, pm)),
        spec((db, L, B, 1, model.MINI.img, model.MINI.img)),
        spec((db, L, B, model.NUM_CLASSES)),
        spec(()),
    ]
    manifest["artifacts"]["mini_local_round"] = lower_one(
        mini_fn, specs, os.path.join(out, "mini_local_round.hlo.txt"))

    # --- D3QN --------------------------------------------------------------
    qcfg = dqn.DqnConfig(args.n_edges, args.train_horizon,
                         hid=args.dqn_hid, fc=args.dqn_fc)
    pq = dqn.param_count(qcfg)
    manifest["models"]["dqn"] = {
        "params": pq,
        "leaves": [{"name": n, "shape": list(s)} for n, s in qcfg.leaves()],
        "bytes": 4 * pq,
    }
    print(f"[dqn] params={pq} ({4 * pq / 1024:.0f} KB)")

    for h in args.horizons:
        hcfg = dqn.DqnConfig(args.n_edges, h, hid=args.dqn_hid,
                             fc=args.dqn_fc)
        q_fn = dqn.make_qvalues_all(hcfg)
        specs = [spec((pq,)), spec((h, hcfg.feat))]
        manifest["artifacts"][f"dqn_q_all_h{h}"] = lower_one(
            q_fn, specs, os.path.join(out, f"dqn_q_all_h{h}.hlo.txt"))

    o = args.dqn_batch
    train_fn = dqn.make_train_step(qcfg, lr=args.dqn_lr)
    specs = [
        spec((pq,)), spec((pq,)), spec((pq,)), spec((pq,)), spec(()),
        spec((o, args.train_horizon, qcfg.feat)),
        spec((o,), jnp.int32), spec((o,), jnp.int32),
        spec((o,)), spec((o,)), spec(()),
    ]
    manifest["artifacts"]["dqn_train"] = lower_one(
        train_fn, specs, os.path.join(out, "dqn_train.hlo.txt"))

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest -> {os.path.join(out, 'manifest.json')}")


if __name__ == "__main__":
    main()
