"""L2 — the HFL models in JAX, built on the L1 Pallas kernel.

Two models, per the paper §VI:

* **HFL CNN** — two 5×5 conv layers (15, 28 output channels), each followed
  by 2×2 max pooling, then two fully-connected layers. Hidden width is
  chosen so the flat parameter vector matches the paper's model sizes
  (z ≈ 448 KB FashionMNIST, ≈ 882 KB CIFAR-10).
* **Mini model ξ** (IKC, §IV-B) — one 2×2 conv (16 ch) + 2×2 pool + one
  linear layer on 1×10×10 crops; ≈10 KB of parameters, used only for
  device clustering (Algorithm 2).

All convolutions are im2col + the Pallas fused matmul; both FC layers are
the Pallas kernel directly, so the entire fwd/bwd FLOP volume is on the L1
hot path.

Parameters cross the Rust↔HLO boundary as a single flat f32 vector; the
leaf layout (name/shape/offset) is exported in artifacts/manifest.json so
the Rust coordinator can He-initialize [41] and aggregate per eq. (2)/(3)
without ever deserializing a pytree.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.matmul import linear

# ---------------------------------------------------------------------------
# Model configurations
# ---------------------------------------------------------------------------

NUM_CLASSES = 10


class CnnConfig:
    """Static architecture description for the HFL CNN."""

    def __init__(self, name: str, in_ch: int, img: int, c1: int, c2: int,
                 hidden: int, ksize: int = 5):
        self.name = name
        self.in_ch = in_ch
        self.img = img
        self.c1 = c1
        self.c2 = c2
        self.hidden = hidden
        self.ksize = ksize
        s1 = img - ksize + 1          # after conv1
        p1 = s1 // 2                  # after pool1
        s2 = p1 - ksize + 1           # after conv2
        self.feat_hw = s2 // 2        # after pool2
        self.feat = self.feat_hw * self.feat_hw * c2

    def leaves(self) -> List[Tuple[str, Tuple[int, ...]]]:
        k = self.ksize
        return [
            ("conv1_w", (self.c1, self.in_ch, k, k)),
            ("conv1_b", (self.c1,)),
            ("conv2_w", (self.c2, self.c1, k, k)),
            ("conv2_b", (self.c2,)),
            ("fc1_w", (self.feat, self.hidden)),
            ("fc1_b", (self.hidden,)),
            ("fc2_w", (self.hidden, NUM_CLASSES)),
            ("fc2_b", (NUM_CLASSES,)),
        ]


# Hidden widths tuned so 4*n_params matches the paper's Table I model sizes
# (448 KB / 882 KB); see DESIGN.md §5.
FMNIST = CnnConfig("fmnist", in_ch=1, img=28, c1=15, c2=28, hidden=220)
CIFAR = CnnConfig("cifar", in_ch=3, img=32, c1=15, c2=28, hidden=295)


class MiniConfig:
    """The IKC auxiliary mini model ξ: 2×2 conv(16) + pool + linear."""

    name = "mini"
    in_ch = 1
    img = 10
    ch = 16
    ksize = 2

    def __init__(self):
        s1 = self.img - self.ksize + 1   # 9
        self.feat_hw = s1 // 2           # 4
        self.feat = self.feat_hw * self.feat_hw * self.ch  # 256

    def leaves(self) -> List[Tuple[str, Tuple[int, ...]]]:
        k = self.ksize
        return [
            ("conv1_w", (self.ch, self.in_ch, k, k)),
            ("conv1_b", (self.ch,)),
            ("fc_w", (self.feat, NUM_CLASSES)),
            ("fc_b", (NUM_CLASSES,)),
        ]


MINI = MiniConfig()

# ---------------------------------------------------------------------------
# Flat-vector parameter handling
# ---------------------------------------------------------------------------


def leaf_layout(leaves) -> List[Dict]:
    """[{name, shape, offset, size}] in flat-vector order."""
    out, off = [], 0
    for name, shape in leaves:
        size = int(math.prod(shape))
        out.append({"name": name, "shape": list(shape),
                    "offset": off, "size": size})
        off += size
    return out


def param_count(leaves) -> int:
    return sum(int(math.prod(s)) for _, s in leaves)


def unflatten(flat, leaves):
    params, off = {}, 0
    for name, shape in leaves:
        size = int(math.prod(shape))
        params[name] = flat[off:off + size].reshape(shape)
        off += size
    return params


def flatten(params, leaves):
    return jnp.concatenate([params[name].reshape(-1) for name, _ in leaves])


# The classifier head is initialized 10× smaller than He: with full-scale
# He the initial logits have std >> 1 (loss ≈ 4.6 instead of ln 10) and
# plain SGD at the paper's learning rates stalls. Standard practice; the
# Rust init (rust/src/model/mod.rs) applies the same rule.
OUTPUT_SCALE = 0.1
_OUTPUT_LEAVES = ("fc2_w", "fc_w")


def init_flat(key, leaves):
    """He-normal init [41] for weights, zeros for biases (oracle for Rust)."""
    chunks = []
    for name, shape in leaves:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            if len(shape) == 4:           # OIHW conv
                fan_in = shape[1] * shape[2] * shape[3]
            else:                          # (in, out) dense
                fan_in = shape[0]
            std = math.sqrt(2.0 / fan_in)
            if name in _OUTPUT_LEAVES:
                std *= OUTPUT_SCALE
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Building blocks (all matmuls on the Pallas kernel)
# ---------------------------------------------------------------------------


def im2col(x, k: int):
    """NCHW -> (N*H'*W', C*k*k) patch matrix for a valid k×k conv.

    The k×k static unroll of slices lowers to k² strided slices + one
    concatenate — XLA fuses this with the downstream (Pallas) matmul's
    HBM→VMEM staging.
    """
    n, c, h, w = x.shape
    ho, wo = h - k + 1, w - k + 1
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(x[:, :, di:di + ho, dj:dj + wo])
    # (k*k, N, C, H', W') -> (N, H', W', C, k*k)
    patches = jnp.stack(cols, axis=0).transpose(1, 3, 4, 2, 0)
    return patches.reshape(n * ho * wo, c * k * k), (n, ho, wo)


def conv2d(x, w_oihw, b, act: str):
    """Valid conv as im2col + Pallas fused matmul. NCHW in, NCHW out."""
    oc, ic, k, _ = w_oihw.shape
    mat, (n, ho, wo) = im2col(x, k)
    # OIHW -> (C*k*k, O), matching the im2col column order (C, k*k)
    wmat = w_oihw.transpose(1, 2, 3, 0).reshape(ic * k * k, oc)
    out = linear(mat, wmat, b, act)
    return out.reshape(n, ho, wo, oc).transpose(0, 3, 1, 2)


def maxpool2(x):
    n, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, : h2 * 2, : w2 * 2].reshape(n, c, h2, 2, w2, 2)
    return x.max(axis=(3, 5))


def softmax_xent(logits, y_onehot):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(y_onehot * logp).sum(axis=-1).mean()


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def cnn_forward(flat, x, cfg: CnnConfig):
    """flat params + x[N, C, H, W] -> logits[N, 10]."""
    p = unflatten(flat, cfg.leaves())
    h = conv2d(x, p["conv1_w"], p["conv1_b"], "relu")
    h = maxpool2(h)
    h = conv2d(h, p["conv2_w"], p["conv2_b"], "relu")
    h = maxpool2(h)
    h = h.transpose(0, 2, 3, 1).reshape(x.shape[0], cfg.feat)
    h = linear(h, p["fc1_w"], p["fc1_b"], "relu")
    return linear(h, p["fc2_w"], p["fc2_b"], "none")


def mini_forward(flat, x, cfg: MiniConfig = MINI):
    p = unflatten(flat, cfg.leaves())
    h = conv2d(x, p["conv1_w"], p["conv1_b"], "relu")
    h = maxpool2(h)
    h = h.transpose(0, 2, 3, 1).reshape(x.shape[0], cfg.feat)
    return linear(h, p["fc_w"], p["fc_b"], "none")


def cnn_loss(flat, x, y_onehot, cfg):
    return softmax_xent(cnn_forward(flat, x, cfg), y_onehot)


def mini_loss(flat, x, y_onehot, cfg: MiniConfig = MINI):
    return softmax_xent(mini_forward(flat, x, cfg), y_onehot)


# ---------------------------------------------------------------------------
# Local training round (eq. 1): L SGD steps over per-step minibatches.
# ---------------------------------------------------------------------------


def local_round(flat, xs, ys, lr, loss_fn):
    """lax.scan of L SGD steps. xs: [L, B, ...], ys: [L, B, 10].

    Returns (updated flat params, mean loss over the L steps).
    """

    def step(p, xy):
        x, y = xy
        lval, g = jax.value_and_grad(loss_fn)(p, x, y)
        return p - lr * g, lval

    final, losses = jax.lax.scan(step, flat, (xs, ys))
    return final, losses.mean()


def make_local_round(cfg):
    loss_fn = functools.partial(cnn_loss, cfg=cfg)

    def fn(flat, xs, ys, lr):
        return local_round(flat, xs, ys, lr, loss_fn)

    return fn


def make_mini_local_round():
    def fn(flat, xs, ys, lr):
        return local_round(flat, xs, ys, lr, mini_loss)

    return fn


def make_local_round_batched(cfg, db: int):
    """vmap over `db` device slots — the L3 device-parallel hot path.

    (params[db,P], xs[db,L,B,C,H,W], ys[db,L,B,10], lr) ->
        (params'[db,P], loss[db])
    """
    single = make_local_round(cfg)

    def fn(flat_b, xs_b, ys_b, lr):
        return jax.vmap(lambda f, x, y: single(f, x, y, lr))(flat_b, xs_b, ys_b)

    return fn


def make_mini_local_round_batched(db: int):
    single = make_mini_local_round()

    def fn(flat_b, xs_b, ys_b, lr):
        return jax.vmap(lambda f, x, y: single(f, x, y, lr))(flat_b, xs_b, ys_b)

    return fn


def make_eval(cfg):
    """(params[P], x[EB, C, H, W]) -> logits[EB, 10]."""

    def fn(flat, x):
        return cnn_forward(flat, x, cfg)

    return fn
