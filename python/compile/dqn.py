"""L2 — Dueling Double Deep Q-Network (D³QN) with a BiLSTM agent (§V).

State (eq. 25) is `((χ_1..χ_t), (χ_t..χ_H))`: the *fixed* per-episode device
feature sequence split at position t. Since actions never enter the state,
one forward LSTM scan produces the prefix hidden for every t, and one
backward scan produces the suffix hidden for every t. `qvalues_all` exploits
this: a single bidirectional scan + vmapped dueling heads yields Q[H, M] for
the whole episode — the Rust request path performs device assignment for an
entire global iteration with ONE PJRT call, and the train step needs two
(online + target) net evaluations per minibatch instead of 3·H.

Architecture per the paper (Fig. 2): one LSTM module with shared parameters
φ for both directions, hidden size `hid`; a shared linear layer; a
state-value head ρ (V) and an advantage head ζ (A); dueling combination
eq. (20); double-DQN target eq. (22); Adam optimizer.

The paper uses hid=256. The default AOT artifact uses hid=64 to keep the
CPU-interpret wall-clock of Algorithm 5 practical; `aot.py --dqn-hid 256`
lowers the paper-sized network (see DESIGN.md §5 substitutions).

All dense math (LSTM gates, heads) routes through the L1 Pallas kernel.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.matmul import linear


class DqnConfig:
    def __init__(self, n_edges: int, horizon: int, hid: int = 64,
                 fc: int = 64):
        self.n_edges = n_edges      # M — action space size
        self.horizon = horizon      # H — episode length (devices/iteration)
        self.feat = n_edges + 3     # F — features per device (eq. 24)
        self.hid = hid
        self.fc = fc

    def leaves(self) -> List[Tuple[str, Tuple[int, ...]]]:
        f, h = self.feat, self.hid
        return [
            # φ — shared LSTM cell, gate order [i, f, g, o]
            ("lstm_wi", (f, 4 * h)),
            ("lstm_wh", (h, 4 * h)),
            ("lstm_b", (4 * h,)),
            # φ — shared trunk on [h_fwd ; h_bwd]
            ("fc_w", (2 * h, self.fc)),
            ("fc_b", (self.fc,)),
            # ρ — state-value head
            ("v_w", (self.fc, 1)),
            ("v_b", (1,)),
            # ζ — advantage head
            ("a_w", (self.fc, self.n_edges)),
            ("a_b", (self.n_edges,)),
        ]


def param_count(cfg: DqnConfig) -> int:
    return sum(int(math.prod(s)) for _, s in cfg.leaves())


def unflatten(flat, cfg: DqnConfig) -> Dict[str, jnp.ndarray]:
    params, off = {}, 0
    for name, shape in cfg.leaves():
        size = int(math.prod(shape))
        params[name] = flat[off:off + size].reshape(shape)
        off += size
    return params


def _lstm_cell(p, x, h, c):
    """One LSTM step on a (B, F) slice; gates via the Pallas kernel."""
    xh = jnp.concatenate([x, h], axis=-1)
    w = jnp.concatenate([p["lstm_wi"], p["lstm_wh"]], axis=0)
    gates = linear(xh, w, p["lstm_b"], "none")
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    return h2, c2


def qvalues_all(flat, feats, cfg: DqnConfig):
    """Q-values for every split position of one episode.

    feats: (H, F) normalized device features (eq. 24, already min-max
    normalized by the caller — the Rust coordinator).
    Returns Q: (H, M) where row t is Q(s_t, ·) per eqs. (20)/(25).
    """
    p = unflatten(flat, cfg)
    h0 = jnp.zeros((1, cfg.hid), jnp.float32)
    c0 = jnp.zeros((1, cfg.hid), jnp.float32)

    def fwd_step(carry, x):
        h, c = carry
        h2, c2 = _lstm_cell(p, x[None, :], h, c)
        return (h2, c2), h2[0]

    # prefix hiddens: hs_f[j] encodes χ_1..χ_{j+1}  (state t = j+1 1-based)
    _, hs_f = jax.lax.scan(fwd_step, (h0, c0), feats)
    # suffix hiddens: hs_b[j] encodes χ_{j+1}..χ_H
    _, hs_b_rev = jax.lax.scan(fwd_step, (h0, c0), feats[::-1])
    hs_b = hs_b_rev[::-1]

    hcat = jnp.concatenate([hs_f, hs_b], axis=-1)        # (H, 2*hid)
    trunk = linear(hcat, p["fc_w"], p["fc_b"], "relu")    # (H, fc)
    v = linear(trunk, p["v_w"], p["v_b"], "none")         # (H, 1)
    a = linear(trunk, p["a_w"], p["a_b"], "none")         # (H, M)
    return v + a - a.mean(axis=-1, keepdims=True)         # eq. (20)


def make_qvalues_all(cfg: DqnConfig):
    def fn(flat, feats):
        return qvalues_all(flat, feats, cfg)

    return fn


# ---------------------------------------------------------------------------
# Double-DQN + Adam train step (eqs. 21–22), whole-step lowered to one HLO.
# ---------------------------------------------------------------------------


def td_loss(flat, flat_tgt, feats_b, t_b, a_b, r_b, done_b, gamma, cfg):
    """Minibatch TD loss. feats_b: (O,H,F); t_b, a_b: (O,) i32; r/done: (O,)."""
    o = feats_b.shape[0]
    rows = jnp.arange(o)

    q_on = jax.vmap(lambda f: qvalues_all(flat, f, cfg))(feats_b)   # (O,H,M)
    q_tg = jax.vmap(lambda f: qvalues_all(flat_tgt, f, cfg))(feats_b)

    t_next = jnp.minimum(t_b + 1, cfg.horizon - 1)
    # double DQN: argmax under the online net, value under the target net
    a_star = jnp.argmax(q_on[rows, t_next], axis=-1)
    q_next = q_tg[rows, t_next, a_star]
    target = r_b + gamma * (1.0 - done_b) * q_next
    target = jax.lax.stop_gradient(target)

    q_sa = q_on[rows, t_b, a_b]
    return jnp.mean((target - q_sa) ** 2)


def make_train_step(cfg: DqnConfig, lr: float = 1e-3, beta1: float = 0.9,
                    beta2: float = 0.999, eps: float = 1e-8):
    """(θ, θ_tgt, m, v, step, feats, t, a, r, done, gamma)
       -> (θ', m', v', loss).  Adam on the flat parameter vector."""

    def fn(flat, flat_tgt, m, v, step, feats_b, t_b, a_b, r_b, done_b, gamma):
        loss, g = jax.value_and_grad(td_loss)(
            flat, flat_tgt, feats_b, t_b, a_b, r_b, done_b, gamma, cfg
        )
        step = step + 1.0
        m2 = beta1 * m + (1.0 - beta1) * g
        v2 = beta2 * v + (1.0 - beta2) * g * g
        mhat = m2 / (1.0 - beta1 ** step)
        vhat = v2 / (1.0 - beta2 ** step)
        flat2 = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        return flat2, m2, v2, loss

    return fn


def init_flat(key, cfg: DqnConfig):
    """Glorot-uniform for weights, zeros for biases (oracle for Rust init)."""
    chunks = []
    for name, shape in cfg.leaves():
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in, fan_out = shape[0], shape[-1]
            lim = math.sqrt(6.0 / (fan_in + fan_out))
            chunks.append(jax.random.uniform(
                sub, shape, jnp.float32, -lim, lim).reshape(-1))
    return jnp.concatenate(chunks)
