//! END-TO-END driver (DESIGN.md §End-to-end validation): the full system on
//! a real small workload, proving all layers compose:
//!
//!   Rust coordinator (L3: IKC scheduling + D³QN assignment + convex
//!   allocation + Algorithm 1/6 orchestration)
//!     → Backend abstraction (pure-Rust NativeBackend here; the same code
//!       drives the PJRT engine when the `pjrt` feature is on)
//!       → native kernels (L1/L2 ports of the JAX model)
//!
//! It (1) trains the D³QN assigner for a few Algorithm-5 episodes,
//! (2) clusters devices with the mini model (Algorithm 2), then (3) runs
//! HFL on synth-fmnist until the target accuracy, logging the loss/accuracy
//! curve and the eq. 13/14 cost accounting. Recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_hfl`

use hfl::allocation::SolverOpts;
use hfl::assignment::drl::DrlAssigner;
use hfl::drl::{DqnTrainConfig, DqnTrainer};
use hfl::experiments::common::clusters_for;
use hfl::fl::{HflConfig, HflTrainer};
use hfl::policy::assigners::D3qnPolicy;
use hfl::policy::{PolicyRegistry, SchedEnv};
use hfl::runtime::{Backend, NativeBackend};
use hfl::scheduling::AuxModel;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let t0 = std::time::Instant::now();
    let backend = NativeBackend::new();

    // ---- phase 1: train the D³QN assignment agent (Algorithm 5) --------
    println!("[1/3] training D³QN assigner (Algorithm 5, reduced episodes)…");
    let mut tcfg = DqnTrainConfig::default();
    tcfg.episodes = 10;
    tcfg.hfel_exchange = 100;
    tcfg.system.model_bits = (backend.manifest().model("fmnist")?.bytes * 8) as f64;
    let mut dqn_trainer = DqnTrainer::new(&backend, tcfg)?;
    let dqn = dqn_trainer.train(|ep, avg| {
        println!("  episode {ep:3}  avg reward {avg:6.1}");
    })?;

    // ---- phase 2: cluster devices (Algorithm 2, mini model ξ) ----------
    println!("[2/3] clustering devices with the mini model (Algorithm 2)…");
    let cfg = HflConfig {
        dataset: "fmnist".into(),
        h: 50,
        lr: 0.05,
        target_acc: 0.95,
        max_iters: 12,
        test_size: 500,
        frac_major: 0.8,
        seed: 2024,
    };
    let mut trainer = HflTrainer::with_default_topology(&backend, cfg)?;
    let clusters = clusters_for(
        &backend, &trainer.topo, &trainer.templates, &trainer.device_data,
        AuxModel::Mini, 10, 2024,
    )?;

    // ---- phase 3: the full HFL framework (Algorithm 6) -----------------
    println!("[3/3] HFL training: IKC + D³QN + convex allocation…");
    let reg = PolicyRegistry::global();
    let mut sched = reg.scheduler(&reg.sched_key("ikc")?, &SchedEnv { seed: 11 })?;
    let mut assigner = D3qnPolicy::new(DrlAssigner::new(&backend, dqn.theta), "d3qn".into());
    let res = trainer.run_policies(
        &mut *sched,
        &mut assigner,
        Some(&clusters),
        11,
        &SolverOpts::default(),
        |r| {
            println!(
                "  iter {:2}  acc {:.3}  loss {:.3}  T_i {:8.1}s  E_i {:7.1}J  msgs {:5.1}MB  assign {:5.1}ms",
                r.iter, r.accuracy, r.train_loss, r.t_i, r.e_i,
                r.msg_bytes / 1e6, r.assign_latency_s * 1e3
            );
        },
    )?;

    println!("\n==== e2e summary ====");
    match res.converged_at {
        Some(i) => println!("reached 95% target accuracy in {i} global iterations"),
        None => println!(
            "final accuracy {:.3} after {} iterations",
            res.final_accuracy(),
            res.records.len()
        ),
    }
    println!(
        "simulated totals: T = {:.1}s, E = {:.1}J, objective = {:.1}, msgs = {:.1}MB",
        res.total_t(),
        res.total_e(),
        res.objective(1.0),
        res.total_msg_bytes() / 1e6
    );
    let s = backend.stats();
    println!(
        "backend: {} kernel calls, {:.1}s exec; wall {:.1}s",
        s.calls, s.exec_secs, t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(
        res.final_accuracy() > 0.5,
        "e2e run failed to learn (acc {})",
        res.final_accuracy()
    );
    println!("E2E OK — all three layers compose.");
    Ok(())
}
