//! Diagnostic: Algorithm 2 clustering quality (ARI) vs auxiliary-model
//! learning rate — the calibration probe behind AuxModel::cluster_lr().
use hfl::data::{partition, SynthSpec, Templates};
use hfl::runtime::{Backend, NativeBackend};
use hfl::scheduling::{cluster_devices, AuxModel};
use hfl::system::{SystemParams, Topology};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let backend = NativeBackend::new();
    let mut params = SystemParams::default();
    params.n_devices = 40;
    let info = backend.manifest().model("fmnist")?;
    params.model_bits = (info.bytes * 8) as f64;
    let mut rng = Rng::new(3);
    let topo = Topology::generate(&params, &mut rng);
    let spec = SynthSpec::fmnist();
    let templates = Templates::generate(&spec, 3);
    let samples: Vec<usize> = topo.num_samples_per_device();
    let dd = partition(40, &samples, 0.8, 3);
    for lr in [0.05f32, 0.2, 0.5] {
        let res =
            cluster_devices(&backend, &topo, &templates, &dd, AuxModel::Mini, 10, lr, &mut rng)?;
        println!("lr {lr}: ARI {:.3}", res.ari);
    }
    Ok(())
}
