//! Burst-traffic scenario (paper §I, §VI-C): the backhaul constrains the
//! message volume PER ROUND, so the operator schedules 30% of devices to
//! flatten uplink bursts. Reports per-iteration message sizes for
//! H ∈ {10, 30, 50, 100} and the per-round burst reduction.
//!
//! Runs on the native backend; the sweepable version of this scenario is
//! `hfl sweep --preset burst` (optionally with `--faults lossy` to see the
//! burst under stragglers/dropout).
//!
//! Run: `cargo run --release --example burst_traffic`

use hfl::assignment::random::RoundRobin;
use hfl::assignment::Assigner;
use hfl::bench::Table;
use hfl::fl::{HflConfig, HflTrainer};
use hfl::runtime::NativeBackend;
use hfl::scheduling::{FedAvg, Scheduler};

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let backend = NativeBackend::new();
    let mut table = Table::new(&["H", "msgs/round (MB)", "burst vs full"]);

    let mut full_burst = 0.0f64;
    for h in [100usize, 50, 30, 10] {
        let cfg = HflConfig {
            dataset: "fmnist".into(),
            h,
            lr: 0.05,
            target_acc: 1.0,
            max_iters: 1,
            test_size: 100,
            frac_major: 0.8,
            seed: 7,
        };
        let trainer = HflTrainer::with_default_topology(&backend, cfg)?;
        let mut sched = FedAvg::new(100, h, 1);
        let scheduled = sched.schedule();
        let assignment = RoundRobin.assign(&trainer.topo, &scheduled);
        let burst = trainer.iter_msg_bytes(&assignment) / 1e6;
        if h == 100 {
            full_burst = burst;
        }
        table.row(&[
            h.to_string(),
            format!("{burst:.1}"),
            format!("{:.0}%", 100.0 * burst / full_burst),
        ]);
    }
    println!("per-round uplink burst vs scheduled share (z = 437 KB model):");
    table.print();
    println!(
        "\nScheduling 30% of devices cuts the per-round burst to ~30% of full\n\
         participation — the paper's recommendation when avoiding burst\n\
         traffic is a key objective (§VII)."
    );
    Ok(())
}
