//! Diagnostic: single-device IID training through the backend's
//! local-round kernel — isolates the eval/data path from FL aggregation
//! dynamics. Loss must fall and accuracy must approach 1.0 within ~10
//! rounds.
use hfl::data::{partition, SynthSpec, Templates, TestSet, NUM_CLASSES};
use hfl::fl::evaluate_accuracy;
use hfl::model::{init_params, Init};
use hfl::runtime::{Backend, NativeBackend};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let backend = NativeBackend::new();
    let c = backend.manifest().consts.clone();
    let info = backend.manifest().model("fmnist")?.clone();
    let spec = SynthSpec::fmnist();
    let templates = Templates::generate(&spec, 1);
    // frac_major=0.1 => exactly uniform-ish (10% majority + rest spread)
    let dd = &partition(1, &vec![700], 0.1, 1)[0];
    let test = TestSet::generate(&templates, 500, 99);
    let mut rng = Rng::new(2);
    let p = info.params;
    let (l, b) = (c.l, c.b);
    // flexible backends run exactly one device slot; fixed-shape ones
    // (PJRT) need the full DB batch, with the extra slots as duplicates
    let slots = if backend.supports_partial_batch() { 1 } else { c.db };
    let pixels = spec.pixels();
    let mut params = init_params(&info, Init::HeNormal, &mut rng);
    let mut xs = vec![0.0f32; slots * l * b * pixels];
    let mut ys = vec![0.0f32; slots * l * b * NUM_CLASSES];
    for round in 0..20 {
        // all slots carry the same params; each gets fresh batches
        let mut pb = vec![0.0f32; slots * p];
        for s in 0..slots {
            pb[s * p..(s + 1) * p].copy_from_slice(&params);
            dd.fill_batch(&templates, &mut rng, l * b,
                &mut xs[s*l*b*pixels..(s+1)*l*b*pixels],
                &mut ys[s*l*b*NUM_CLASSES..(s+1)*l*b*NUM_CLASSES]);
        }
        let (updated, losses) = backend.local_round("fmnist", &pb, &xs, &ys, 0.05)?;
        // chain slot 0's params (l SGD steps per round, looped over rounds)
        params = updated[0..p].to_vec();
        let loss = losses[0];
        if round % 2 == 1 {
            let acc = evaluate_accuracy(&backend, "fmnist", &params, &test, 1, 28)?;
            println!("round {round:2} loss {loss:.3} acc {acc:.3}");
        }
    }
    Ok(())
}
