//! Diagnostic: single-device IID training through the AOT artifacts —
//! isolates the eval/data path from FL aggregation dynamics. Loss must
//! fall and accuracy must approach 1.0 within ~10 rounds.
use hfl::data::{partition, SynthSpec, Templates, TestSet, NUM_CLASSES};
use hfl::fl::evaluate_accuracy;
use hfl::model::{init_params, Init};
use hfl::runtime::{Arg, Engine};
use hfl::util::Rng;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    let c = engine.manifest.consts.clone();
    let info = engine.manifest.model("fmnist")?.clone();
    let spec = SynthSpec::fmnist();
    let templates = Templates::generate(&spec, 1);
    // frac_major=0.1 => exactly uniform-ish (10% majority + rest spread)
    let dd = &partition(1, &vec![700], 0.1, 1)[0];
    let test = TestSet::generate(&templates, 500, 99);
    let mut rng = Rng::new(2);
    let p = info.params;
    let (db, l, b) = (c.db, c.l, c.b);
    let pixels = spec.pixels();
    let mut params = init_params(&info, Init::HeNormal, &mut rng);
    let mut xs = vec![0.0f32; db * l * b * pixels];
    let mut ys = vec![0.0f32; db * l * b * NUM_CLASSES];
    for round in 0..20 {
        // all DB slots carry the same params; each gets fresh batches
        let mut pb = vec![0.0f32; db * p];
        for s in 0..db {
            pb[s * p..(s + 1) * p].copy_from_slice(&params);
            dd.fill_batch(&templates, &mut rng, l * b,
                &mut xs[s*l*b*pixels..(s+1)*l*b*pixels],
                &mut ys[s*l*b*NUM_CLASSES..(s+1)*l*b*NUM_CLASSES]);
        }
        let out = engine.run("local_round_fmnist", &[
            Arg::F32(&pb, &[db as i64, p as i64]),
            Arg::F32(&xs, &[db as i64, l as i64, b as i64, 1, 28, 28]),
            Arg::F32(&ys, &[db as i64, l as i64, b as i64, NUM_CLASSES as i64]),
            Arg::ScalarF32(0.05),
        ])?;
        // chain slot 0's params (sequential SGD: db*l steps per round... no,
        // slot 0 only does l steps; but we loop rounds)
        params = out[0][0..p].to_vec();
        let loss = out[1][0];
        if round % 2 == 1 {
            let acc = evaluate_accuracy(&engine, "fmnist", &params, &test, 1, 28)?;
            println!("round {round:2} loss {loss:.3} acc {acc:.3}");
        }
    }
    Ok(())
}
