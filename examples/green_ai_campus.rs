//! Green-AI scenario (paper §I, §VI-C): a campus IoT deployment where
//! ENERGY is the key objective. Sets λ = 0.1 (energy-weighted objective)
//! and compares scheduling 30% of devices (the paper's Green-AI
//! recommendation) against scheduling everyone, reporting energy, time and
//! message volume to the same target accuracy.
//!
//! Run: `cargo run --release --example green_ai_campus`

use hfl::allocation::SolverOpts;
use hfl::assignment::random::RoundRobin;
use hfl::bench::Table;
use hfl::experiments::common::clusters_for;
use hfl::fl::{HflConfig, HflTrainer};
use hfl::policy::assigners::FromAssigner;
use hfl::policy::{PolicyRegistry, SchedEnv};
use hfl::runtime::NativeBackend;
use hfl::scheduling::AuxModel;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let backend = NativeBackend::new();
    let target = 0.93;

    let mut table = Table::new(&[
        "H", "share", "iters", "final acc", "E (J)", "T (s)", "msgs (MB)",
    ]);
    for h in [30usize, 100] {
        let cfg = HflConfig {
            dataset: "fmnist".into(),
            h,
            lr: 0.05,
            target_acc: target,
            max_iters: 10,
            test_size: 400,
            frac_major: 0.8,
            seed: 42,
        };
        let mut trainer = HflTrainer::with_default_topology(&backend, cfg)?;
        trainer.topo.params.lambda = 0.1; // Green AI: energy-dominant
        let clusters = clusters_for(
            &backend, &trainer.topo, &trainer.templates, &trainer.device_data,
            AuxModel::Mini, 10, 42,
        )?;
        let reg = PolicyRegistry::global();
        let mut sched = reg.scheduler(&reg.sched_key("ikc")?, &SchedEnv { seed: 1 })?;
        let mut assigner = FromAssigner::new(RoundRobin, "round-robin");
        let res = trainer.run_policies(
            &mut *sched,
            &mut assigner,
            Some(&clusters),
            1,
            &SolverOpts::default(),
            |r| {
                println!("H={h} iter {} acc {:.3} E_i {:.1}J", r.iter, r.accuracy, r.e_i);
            },
        )?;
        table.row(&[
            h.to_string(),
            format!("{}%", h),
            res.converged_at.map_or("—".into(), |i| i.to_string()),
            format!("{:.3}", res.final_accuracy()),
            format!("{:.1}", res.total_e()),
            format!("{:.1}", res.total_t()),
            format!("{:.1}", res.total_msg_bytes() / 1e6),
        ]);
    }
    println!("\nGreen-AI campus: 30% scheduling vs full participation (λ=0.1):");
    table.print();
    Ok(())
}
