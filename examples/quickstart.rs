//! Quickstart: run one scheduled+assigned+allocated HFL training loop on
//! the pure-Rust native backend, print accuracy and costs.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Instant;

use hfl::allocation::SolverOpts;
use hfl::assignment::random::RoundRobin;
use hfl::fl::{HflConfig, HflTrainer};
use hfl::runtime::{Backend, NativeBackend};
use hfl::scheduling::FedAvg;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let t0 = Instant::now();
    let backend = NativeBackend::new();

    let cfg = HflConfig {
        dataset: "fmnist".into(),
        h: 30,
        lr: 0.05,
        target_acc: 1.0,
        max_iters: 10,
        test_size: 300,
        frac_major: 0.8,
        seed: 7,
    };
    let mut trainer = HflTrainer::with_default_topology(&backend, cfg)?;
    let mut sched = FedAvg::new(100, 30, 1);
    let mut assigner = RoundRobin;
    let res = trainer.run(&mut sched, &mut assigner, &SolverOpts::default(), |r| {
        println!(
            "iter {} acc {:.3} loss {:.3} T_i {:.1}s E_i {:.1}J ({} devices)",
            r.iter, r.accuracy, r.train_loss, r.t_i, r.e_i, r.n_scheduled
        );
    })?;
    let s = backend.stats();
    println!(
        "done: final acc {:.3}; backend {} calls, exec {:.2}s, wall {:.2}s",
        res.final_accuracy(),
        s.calls,
        s.exec_secs,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
