//! Quickstart: load the AOT artifacts, run one scheduled+assigned+allocated
//! HFL global iteration, print accuracy and costs.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use std::time::Instant;

use hfl::allocation::SolverOpts;
use hfl::assignment::random::RoundRobin;
use hfl::fl::{HflConfig, HflTrainer};
use hfl::runtime::Engine;
use hfl::scheduling::FedAvg;

fn main() -> anyhow::Result<()> {
    hfl::util::logging::init(1);
    let t0 = Instant::now();
    let engine = Engine::open(std::path::Path::new("artifacts"))?;
    println!("engine open: {:.2}s", t0.elapsed().as_secs_f64());

    let cfg = HflConfig {
        dataset: "fmnist".into(),
        h: 30,
        lr: 0.05,
        target_acc: 1.0,
        max_iters: 10,
        test_size: 300,
        frac_major: 0.8,
        seed: 7,
    };
    let mut trainer = HflTrainer::with_default_topology(&engine, cfg)?;
    let mut sched = FedAvg::new(100, 30, 1);
    let mut assigner = RoundRobin;
    let res = trainer.run(&mut sched, &mut assigner, &SolverOpts::default(), |r| {
        println!(
            "iter {} acc {:.3} loss {:.3} T_i {:.1}s E_i {:.1}J ({} devices)",
            r.iter, r.accuracy, r.train_loss, r.t_i, r.e_i, r.n_scheduled
        );
    })?;
    let s = engine.stats();
    println!(
        "done: final acc {:.3}; engine {} calls, exec {:.2}s, compile {:.2}s, wall {:.2}s",
        res.final_accuracy(),
        s.calls,
        s.exec_secs,
        s.compile_secs,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
